//! Heterogeneous processor graphs and execution-cost models.
//!
//! A [`Platform`] is the paper's resource graph `G_r(V_r, C_r)`: a set of
//! processor *classes* with a communication startup latency `L(p)` per class
//! and a bandwidth `c[p][q]` per ordered pair of classes. Communication
//! between tasks co-located on the same class costs zero (Definition 3).
//!
//! Execution costs `C_comp(t, p)` are a dense `v × P` matrix produced by one
//! of two [`CostModel`]s at generation time:
//!
//! * **Classic** (eq. 5): `w_{i,j} ~ U(w_i(1-β/2), w_i(1+β/2))` — the
//!   Topcuoglu-style heterogeneity factor; a task is at most ~3× faster on
//!   its best processor than its worst.
//! * **Two-weight** (eq. 6): tasks and processors carry two weights drawn
//!   from intervals (I₁, I₂); `cost(t,p) = w₁(t)/W₁(p) + w₀(t)/W₀(p)`. This
//!   produces *accelerator-like* heterogeneity: a task can be orders of
//!   magnitude faster on the processor class that matches it.

use crate::util::rng::Xoshiro256;

/// A heterogeneous machine: `P` processor classes with per-class
/// communication parameters.
#[derive(Clone, Debug)]
pub struct Platform {
    p: usize,
    /// `L(p)` — communication startup latency paid by the *sender* class.
    startup: Vec<f64>,
    /// `c[p*P+q]` — link bandwidth between classes `p` and `q` (data/time).
    bandwidth: Vec<f64>,
    /// Two-weight model processor weights `(W0, W1)` per class, when built
    /// by [`Platform::two_weight`]; empty otherwise.
    weights: Vec<(f64, f64)>,
    /// Precomputed mean-comm factors (perf: `mean_comm_cost` is called once
    /// per edge by every rank computation; recomputing the O(P²) average
    /// each time would make CPOP/HEFT rank sweeps O(P²e) — see
    /// EXPERIMENTS.md §Mean-comm precomputation).
    /// `mean_comm_cost(d) = mean_startup + d * mean_inv_bw`.
    mean_startup: f64,
    /// mean reciprocal bandwidth over distinct ordered pairs
    mean_inv_bw: f64,
}

impl Platform {
    /// Uniform platform: all links share `bandwidth`, all classes share
    /// `startup`. This is the communication model of the paper's RGG
    /// experiments (heterogeneity lives in the edge data volumes).
    pub fn uniform(p: usize, bandwidth: f64, startup: f64) -> Self {
        assert!(p >= 1);
        assert!(bandwidth > 0.0);
        Self::finish(p, vec![startup; p], vec![bandwidth; p * p], Vec::new())
    }

    /// Compute the cached mean-comm factors and assemble the platform.
    ///
    /// Invariant (the `P == 1` edge case): the mean communication cost is
    /// an average over *distinct ordered class pairs*. A single-class
    /// platform has no distinct pairs — all communication is co-located and
    /// costs zero by Definition 3 — so both factors stay `0.0` and
    /// [`Platform::mean_comm_cost`] returns exactly `0` for any payload.
    /// This is deliberate, not a division-by-zero dodge: averaging-based
    /// ranks (CPOP/HEFT) then degenerate to plain longest paths on task
    /// weights, which makes every scheduler agree on single-class chains
    /// (see `single_class_schedulers_agree_on_chain` below and
    /// EXPERIMENTS.md §Determinism).
    fn finish(
        p: usize,
        startup: Vec<f64>,
        bandwidth: Vec<f64>,
        weights: Vec<(f64, f64)>,
    ) -> Self {
        let (mut ms, mut mib) = (0.0, 0.0);
        if p > 1 {
            let pairs = (p * (p - 1)) as f64;
            // each sender's startup is paid for (p-1) destinations
            ms = startup.iter().sum::<f64>() * (p - 1) as f64 / pairs;
            for l in 0..p {
                for j in 0..p {
                    if l != j {
                        mib += 1.0 / bandwidth[l * p + j];
                    }
                }
            }
            mib /= pairs;
        }
        // else: no distinct pairs ⇒ zero mean comm (ms = mib = 0.0)
        Self {
            p,
            startup,
            bandwidth,
            weights,
            mean_startup: ms,
            mean_inv_bw: mib,
        }
    }

    /// Fully heterogeneous platform: per-class startup in
    /// `[startup_lo, startup_hi)`, per-pair bandwidth in `[bw_lo, bw_hi)`
    /// (symmetric). Models NUMA/cluster-style link heterogeneity (§3 of the
    /// paper motivates this case).
    pub fn random_links(
        p: usize,
        rng: &mut Xoshiro256,
        bw_lo: f64,
        bw_hi: f64,
        startup_lo: f64,
        startup_hi: f64,
    ) -> Self {
        assert!(p >= 1);
        let startup = (0..p).map(|_| rng.uniform(startup_lo, startup_hi)).collect();
        let mut bandwidth = vec![0.0; p * p];
        for i in 0..p {
            for j in i..p {
                let bw = rng.uniform(bw_lo, bw_hi);
                bandwidth[i * p + j] = bw;
                bandwidth[j * p + i] = bw;
            }
        }
        Self::finish(p, startup, bandwidth, Vec::new())
    }

    /// Two-weight-model platform (§7.1): each class draws `(W0, W1)` from
    /// the resource intervals `I₁ = [1e2, 1e3]`, `I₂ = [1e3, 1e4]`; with
    /// probability `beta` the order is `(I₁, I₂)`, otherwise interchanged.
    /// Links are uniform (`bandwidth`, `startup`).
    pub fn two_weight(
        p: usize,
        beta: f64,
        rng: &mut Xoshiro256,
        bandwidth: f64,
        startup: f64,
    ) -> Self {
        let mut plat = Self::uniform(p, bandwidth, startup);
        plat.weights = (0..p)
            .map(|_| {
                let a = rng.log_uniform(1e2, 1e3);
                let b = rng.log_uniform(1e3, 1e4);
                if rng.chance(beta) {
                    (a, b)
                } else {
                    (b, a)
                }
            })
            .collect();
        plat
    }

    /// Rebuild a platform from raw parts — the inverse of
    /// [`crate::graph::io::platform_to_json`]. `startup` must have `p`
    /// entries, `bandwidth` `p × p` (row-major), and `weights` either `p`
    /// entries (two-weight platforms) or none. Validates instead of
    /// panicking so untrusted service input cannot kill a worker.
    pub fn from_parts(
        p: usize,
        startup: Vec<f64>,
        bandwidth: Vec<f64>,
        weights: Vec<(f64, f64)>,
    ) -> Result<Self, String> {
        if p < 1 {
            return Err("platform needs at least one class".to_string());
        }
        if startup.len() != p {
            return Err(format!("startup has {} entries, expected {p}", startup.len()));
        }
        if bandwidth.len() != p * p {
            return Err(format!(
                "bandwidth has {} entries, expected {}",
                bandwidth.len(),
                p * p
            ));
        }
        if !weights.is_empty() && weights.len() != p {
            return Err(format!("weights has {} entries, expected {p} or 0", weights.len()));
        }
        for (i, &s) in startup.iter().enumerate() {
            if !(s >= 0.0) || !s.is_finite() {
                return Err(format!("startup[{i}] = {s} must be finite and >= 0"));
            }
        }
        for (i, &b) in bandwidth.iter().enumerate() {
            if !(b > 0.0) || !b.is_finite() {
                return Err(format!("bandwidth[{i}] = {b} must be finite and > 0"));
            }
        }
        Ok(Self::finish(p, startup, bandwidth, weights))
    }

    /// The two-weight capacities of every class: `p` entries for platforms
    /// built by [`Platform::two_weight`] (or [`Platform::from_parts`] with
    /// weights), empty otherwise. Serialization-friendly counterpart of the
    /// panicking per-class [`Platform::class_weights`].
    pub fn class_weight_table(&self) -> &[(f64, f64)] {
        &self.weights
    }

    /// Number of processor classes `P`.
    pub fn num_classes(&self) -> usize {
        self.p
    }

    /// `L(p)` — startup latency of class `p`.
    pub fn startup(&self, p: usize) -> f64 {
        self.startup[p]
    }

    /// Bandwidth between classes `p` and `q`.
    pub fn bandwidth(&self, p: usize, q: usize) -> f64 {
        self.bandwidth[p * self.p + q]
    }

    /// Two-weight processor weights `(W0, W1)` of class `p`.
    /// Panics when the platform was not built by [`Platform::two_weight`].
    pub fn class_weights(&self, p: usize) -> (f64, f64) {
        self.weights[p]
    }

    /// Definition 3: communication cost of moving `data` units from a task
    /// on class `pl` to a task on class `pj`. Zero when co-located.
    #[inline]
    pub fn comm_cost(&self, pl: usize, pj: usize, data: f64) -> f64 {
        if pl == pj {
            0.0
        } else {
            self.startup[pl] + data / self.bandwidth[pl * self.p + pj]
        }
    }

    /// Mean communication cost over all *distinct* ordered class pairs —
    /// the scalarisation CPOP/HEFT use (they "set the comm costs of edges
    /// with mean values", Algorithm 2 line 2). Exactly zero when `P == 1`:
    /// with a single class there are no distinct pairs and all transfers
    /// are co-located (see [`Platform::finish`] for the invariant).
    /// O(1): the pair averages are precomputed at construction.
    #[inline]
    pub fn mean_comm_cost(&self, data: f64) -> f64 {
        self.mean_startup + data * self.mean_inv_bw
    }

    /// Field-by-field content equality over exactly what the algorithms
    /// read (class count, startups, bandwidths, two-weight capacities).
    /// `Platform` deliberately has no `PartialEq` — content equality is a
    /// deliberate act at interning boundaries (the service's hash-collision
    /// guard, sweep-level context sharing), not an incidental comparison.
    pub fn content_eq(&self, other: &Platform) -> bool {
        self.p == other.p
            && self.startup == other.startup
            && self.bandwidth == other.bandwidth
            && self.weights == other.weights
    }
}

/// How execution costs `C_comp(t, p)` are generated.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostModel {
    /// eq. 5 — `w_{i,j} ~ U(w_i(1-β/2), w_i(1+β/2))`, β ∈ [0, 1].
    Classic {
        /// heterogeneity factor β (paper values {10,25,50,75,95} are
        /// percentages; pass them /100).
        beta: f64,
    },
    /// eq. 6 — two-weight interval model; the interval pair selects the
    /// workload family.
    TwoWeight {
        /// probability of drawing `(I₁, I₂)` in order (β in §7.1).
        beta: f64,
        /// second interval low bound (I₂.lo): 1e3 (low), 1e4 (medium), 1e5 (high)
        i2_lo: f64,
        /// second interval high bound (I₂.hi): 1e4 / 1e5 / 1e6
        i2_hi: f64,
    },
}

impl CostModel {
    /// The two-weight model for the paper's RGG-low workload.
    pub fn two_weight_low(beta: f64) -> Self {
        CostModel::TwoWeight {
            beta,
            i2_lo: 1e3,
            i2_hi: 1e4,
        }
    }

    /// RGG-medium.
    pub fn two_weight_medium(beta: f64) -> Self {
        CostModel::TwoWeight {
            beta,
            i2_lo: 1e4,
            i2_hi: 1e5,
        }
    }

    /// RGG-high.
    pub fn two_weight_high(beta: f64) -> Self {
        CostModel::TwoWeight {
            beta,
            i2_lo: 1e5,
            i2_hi: 1e6,
        }
    }

    /// Generate the dense `v × P` execution-cost matrix for tasks with base
    /// weights `w` (classic) or fresh two-weight draws (two-weight model).
    ///
    /// Returns `(comp, task_scalar_weight)` where `task_scalar_weight[i]` is
    /// the scalar weight used to scale edge data volumes — always the
    /// structural base weight `w_i`: the paper's two-weight workload
    /// families share the classic structure *and edge weights*, differing
    /// only in execution times (§7.1).
    pub fn generate(
        &self,
        w: &[f64],
        platform: &Platform,
        rng: &mut Xoshiro256,
    ) -> (Vec<f64>, Vec<f64>) {
        let p = platform.num_classes();
        let v = w.len();
        let mut comp = vec![0f64; v * p];
        let mut scalar = vec![0f64; v];
        match *self {
            CostModel::Classic { beta } => {
                assert!((0.0..=1.0).contains(&beta), "beta must be in [0,1]");
                for i in 0..v {
                    for j in 0..p {
                        comp[i * p + j] =
                            rng.uniform(w[i] * (1.0 - beta / 2.0), w[i] * (1.0 + beta / 2.0))
                                .max(1e-9);
                    }
                    scalar[i] = w[i];
                }
            }
            CostModel::TwoWeight { beta, i2_lo, i2_hi } => {
                assert!(
                    !platform.weights.is_empty(),
                    "two-weight cost model requires Platform::two_weight"
                );
                for i in 0..v {
                    let a = rng.log_uniform(1e2, 1e3);
                    let b = rng.log_uniform(i2_lo, i2_hi);
                    let (w0, w1) = if rng.chance(beta) { (a, b) } else { (b, a) };
                    for j in 0..p {
                        let (cap0, cap1) = platform.class_weights(j);
                        comp[i * p + j] = w1 / cap1 + w0 / cap0;
                    }
                    // Edge-volume scale for CCR: the paper leaves the
                    // two-weight vertex "weight" scalar unspecified (tasks
                    // have two weights). We use the task's *minimum*
                    // execution time so CCR measures communication against
                    // the cost a well-mapped task actually has — using the
                    // cross-class mean instead would let the slow classes
                    // inflate every edge and drown the heterogeneity signal
                    // (DESIGN.md §6 records this interpretation).
                    let mut mn = f64::INFINITY;
                    for j in 0..p {
                        mn = mn.min(comp[i * p + j]);
                    }
                    scalar[i] = mn;
                }
            }
        }
        (comp, scalar)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_comm_costs() {
        let p = Platform::uniform(3, 2.0, 0.5);
        assert_eq!(p.comm_cost(0, 0, 100.0), 0.0);
        assert_eq!(p.comm_cost(0, 1, 100.0), 0.5 + 50.0);
        assert_eq!(p.num_classes(), 3);
    }

    #[test]
    fn mean_comm_excludes_diagonal() {
        let p = Platform::uniform(2, 1.0, 0.0);
        // only pairs (0,1) and (1,0), each costing data
        assert_eq!(p.mean_comm_cost(10.0), 10.0);
        let p1 = Platform::uniform(1, 1.0, 0.0);
        assert_eq!(p1.mean_comm_cost(10.0), 0.0);
    }

    #[test]
    fn single_class_schedulers_agree_on_chain() {
        // The P == 1 invariant end to end: no distinct pairs ⇒ zero mean
        // comm ⇒ averaging-based ranks are exact longest paths, and CPOP,
        // HEFT and CEFT-CPOP all produce the same serial chain schedule
        // with the same makespan as the CEFT critical-path length.
        use crate::graph::TaskGraph;
        use crate::model::{CostMatrix, InstanceRef};
        use crate::sched::Scheduler as _;
        let g = TaskGraph::from_edges(4, &[(0, 1, 7.0), (1, 2, 3.0), (2, 3, 11.0)]);
        // nonzero startup + modest bandwidth: irrelevant when co-located
        let plat = Platform::uniform(1, 0.5, 2.0);
        let comp = CostMatrix::new(1, vec![4.0, 6.0, 5.0, 2.0]);
        let serial: f64 = comp.as_slice().iter().sum();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let cpop = crate::sched::cpop::Cpop.schedule(inst);
        let heft = crate::sched::heft::Heft.schedule(inst);
        let cc = crate::sched::ceft_cpop::CeftCpop.schedule(inst);
        for s in [&cpop, &heft, &cc] {
            s.validate(inst).unwrap();
            assert!((s.makespan() - serial).abs() < 1e-12);
        }
        let cp = crate::cp::ceft::find_critical_path(inst);
        assert!((cp.length - serial).abs() < 1e-12);
        assert!(cp.path.iter().all(|s| s.class == 0));
    }

    #[test]
    fn random_links_symmetric_bandwidth() {
        let mut rng = Xoshiro256::new(1);
        let p = Platform::random_links(4, &mut rng, 0.5, 1.5, 0.0, 0.1);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(p.bandwidth(i, j), p.bandwidth(j, i));
                assert!(p.bandwidth(i, j) >= 0.5 && p.bandwidth(i, j) < 1.5);
            }
        }
    }

    #[test]
    fn classic_model_range() {
        let mut rng = Xoshiro256::new(2);
        let plat = Platform::uniform(4, 1.0, 0.0);
        let w = vec![100.0; 10];
        let (comp, scalar) = CostModel::Classic { beta: 0.5 }.generate(&w, &plat, &mut rng);
        assert_eq!(comp.len(), 40);
        assert_eq!(scalar, w);
        for &c in &comp {
            assert!((75.0..=125.0).contains(&c), "c={c}");
        }
    }

    #[test]
    fn two_weight_model_heterogeneity() {
        let mut rng = Xoshiro256::new(3);
        let plat = Platform::two_weight(8, 0.5, &mut rng, 1.0, 0.0);
        let w = vec![1.0; 200]; // base weights unused by two-weight
        let (comp, scalar) =
            CostModel::two_weight_high(0.5).generate(&w, &plat, &mut rng);
        let costs = crate::model::CostMatrix::new(8, comp);
        // expect large best/worst ratios for at least some tasks
        let mut max_ratio: f64 = 0.0;
        for t in 0..200 {
            let mut worst: f64 = 0.0;
            for j in 0..8 {
                worst = worst.max(costs.get(t, j));
            }
            max_ratio = max_ratio.max(worst / costs.min(t));
        }
        assert!(
            max_ratio > 3.0,
            "two-weight high model should exceed classic's 3x bound, got {max_ratio}"
        );
        // scalar weight is the best-case execution time (CCR anchor)
        assert!((scalar[0] - costs.min(0)).abs() < 1e-12);
    }

    #[test]
    fn from_parts_roundtrips_and_validates() {
        let mut rng = Xoshiro256::new(6);
        let orig = Platform::random_links(3, &mut rng, 0.5, 1.5, 0.0, 0.2);
        let startup: Vec<f64> = (0..3).map(|j| orig.startup(j)).collect();
        let mut bw = Vec::new();
        for a in 0..3 {
            for b in 0..3 {
                bw.push(orig.bandwidth(a, b));
            }
        }
        let back = Platform::from_parts(3, startup, bw, Vec::new()).unwrap();
        assert_eq!(back.num_classes(), 3);
        for a in 0..3 {
            for b in 0..3 {
                assert_eq!(back.bandwidth(a, b), orig.bandwidth(a, b));
            }
            assert_eq!(back.startup(a), orig.startup(a));
        }
        // cached mean factors are reproduced exactly
        assert_eq!(back.mean_comm_cost(7.0), orig.mean_comm_cost(7.0));
        // validation errors instead of panics
        assert!(Platform::from_parts(0, vec![], vec![], vec![]).is_err());
        assert!(Platform::from_parts(2, vec![0.0], vec![1.0; 4], vec![]).is_err());
        assert!(Platform::from_parts(2, vec![0.0; 2], vec![1.0; 3], vec![]).is_err());
        assert!(Platform::from_parts(2, vec![0.0; 2], vec![0.0; 4], vec![]).is_err());
        assert!(Platform::from_parts(2, vec![0.0; 2], vec![1.0; 4], vec![(1.0, 1.0)]).is_err());
    }

    #[test]
    fn content_eq_compares_all_algorithm_visible_fields() {
        let mut rng = Xoshiro256::new(8);
        let a = Platform::random_links(3, &mut rng, 0.5, 1.5, 0.0, 0.2);
        let same = Platform::from_parts(
            3,
            (0..3).map(|j| a.startup(j)).collect(),
            (0..9).map(|i| a.bandwidth(i / 3, i % 3)).collect(),
            Vec::new(),
        )
        .unwrap();
        assert!(a.content_eq(&same));
        assert!(!a.content_eq(&Platform::uniform(3, 1.0, 0.0)));
        assert!(!a.content_eq(&Platform::uniform(2, 1.0, 0.0)));
        // two-weight capacities participate
        let mut rng2 = Xoshiro256::new(9);
        let tw = Platform::two_weight(3, 0.5, &mut rng2, 1.0, 0.0);
        assert!(!tw.content_eq(&Platform::uniform(3, 1.0, 0.0)));
        assert!(tw.content_eq(&tw.clone()));
    }

    #[test]
    #[should_panic(expected = "two-weight cost model requires")]
    fn two_weight_needs_platform_weights() {
        let mut rng = Xoshiro256::new(4);
        let plat = Platform::uniform(2, 1.0, 0.0);
        CostModel::two_weight_low(0.5).generate(&[1.0], &plat, &mut rng);
    }
}
