//! Integration tests for the online scheduling service: protocol error
//! paths end to end, cache hit-vs-miss determinism (bit-identical repeat
//! responses, consistent with `cp::ceft`'s tie-breaking guarantees),
//! equivalence with the batch harness, and a concurrent TCP smoke test.

use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::{build_instance, run_cell, ALGOS};
use ceft::graph::io;
use ceft::platform::Platform;
use ceft::sched::Algorithm;
use ceft::service::{Engine, EngineConfig, FaultPlan, Server};
use ceft::util::json::Json;
use std::io::{BufRead as _, BufReader, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

fn smoke_cell() -> ceft::exp::cells::Cell {
    grid(Workload::RggClassic, Scale::Smoke)[0]
}

fn instance_line(op: &str, algo: Option<&str>, cell: &ceft::exp::cells::Cell) -> String {
    let (platform, inst) = build_instance(cell);
    let algo_field = algo
        .map(|a| format!(r#""algorithm":"{a}","#))
        .unwrap_or_default();
    format!(
        r#"{{"op":"{op}",{algo_field}"instance":{},"platform":{}}}"#,
        io::instance_to_json(&inst).to_string(),
        io::platform_to_json(&platform).to_string()
    )
}

fn without_cached(j: &Json) -> Json {
    match j.clone() {
        Json::Obj(mut m) => {
            m.remove("cached");
            Json::Obj(m)
        }
        other => other,
    }
}

#[test]
fn service_matches_batch_schedule_and_cp() {
    let engine = Engine::with_defaults();
    let cell = smoke_cell();
    let row = run_cell(&cell);
    // every registry algorithm returns exactly the batch makespan
    for (i, name) in ALGOS.iter().enumerate() {
        let (resp, _) = engine.handle_line(&instance_line("schedule", Some(name), &cell));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{name}: {resp:?}");
        assert_eq!(
            resp.get("makespan").and_then(Json::as_f64),
            Some(row.algos[i].makespan),
            "{name} makespan diverged from batch `repro schedule`"
        );
        // the embedded schedule round-trips into a legal schedule
        let (platform, inst) = build_instance(&cell);
        let s = io::schedule_from_json(resp.get("schedule").unwrap()).unwrap();
        s.validate(inst.bind(&platform)).unwrap();
    }
    // critical path matches batch `repro cp`
    let (resp, _) = engine.handle_line(&instance_line("cp", None, &cell));
    assert_eq!(
        resp.get("length").and_then(Json::as_f64),
        Some(row.cpl_ceft),
        "CEFT CPL diverged from batch `repro cp`"
    );
}

#[test]
fn repeat_requests_are_cached_and_bit_identical() {
    let engine = Engine::with_defaults();
    let cell = smoke_cell();
    let line = instance_line("schedule", Some("CEFT-CPOP"), &cell);
    let (first, _) = engine.handle_line(&line);
    let (second, _) = engine.handle_line(&line);
    assert_eq!(first.get("cached"), Some(&Json::Bool(false)));
    assert_eq!(second.get("cached"), Some(&Json::Bool(true)));
    // bit-identical apart from the cached flag — serialized text included
    assert_eq!(
        without_cached(&first).to_string(),
        without_cached(&second).to_string()
    );
    // the stats endpoint records exactly one hit and one miss
    let (stats, _) = engine.handle_line(r#"{"op":"stats"}"#);
    let sched = stats.get("sched_cache").unwrap();
    assert_eq!(sched.get("hits").and_then(Json::as_f64), Some(1.0));
    assert_eq!(sched.get("misses").and_then(Json::as_f64), Some(1.0));

    // a *fresh* engine recomputes the same bits (no hidden global state)
    let other = Engine::with_defaults();
    let (recomputed, _) = other.handle_line(&line);
    assert_eq!(
        without_cached(&first).to_string(),
        without_cached(&recomputed).to_string()
    );
}

#[test]
fn protocol_error_paths_return_errors_and_keep_serving() {
    let engine = Engine::with_defaults();
    for bad in [
        "definitely not json",
        "{}",
        r#"{"op":"wat"}"#,
        r#"{"op":"schedule","instance":{"n":1,"p":1,"edges":[],"comp":[1]}}"#, // no algorithm
        r#"{"op":"schedule","algorithm":"nope","instance":{"n":1,"p":1,"edges":[],"comp":[1]}}"#,
        r#"{"op":"cp"}"#,                                   // no instance or id
        r#"{"op":"cp","id":"not-hex"}"#,
        r#"{"op":"cp","id":"00000000000000aa"}"#,           // unknown handle
        r#"{"op":"cp","instance":{"n":2,"p":1,"edges":[[0,1,1.0],[1,0,1.0]],"comp":[1,2]}}"#, // cycle
        r#"{"op":"cp","instance":{"n":0,"p":1,"edges":[],"comp":[]}}"#,
        r#"{"op":"evict","id":"0000000000000001"}"#,        // nothing interned
    ] {
        let (resp, shutdown) = engine.handle_line(bad);
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(false)),
            "accepted bad request: {bad}"
        );
        assert!(resp.get("error").and_then(Json::as_str).is_some());
        assert!(!shutdown);
    }
    // engine still healthy
    let (ok, _) = engine.handle_line(&instance_line("cp", None, &smoke_cell()));
    assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
}

#[test]
fn submit_then_request_by_handle() {
    let engine = Engine::with_defaults();
    let cell = smoke_cell();
    let (submitted, _) = engine.handle_line(&instance_line("submit", None, &cell));
    let id = submitted
        .get("id")
        .and_then(Json::as_str)
        .expect("submit returns a handle")
        .to_string();
    let (cp, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
    assert_eq!(cp.get("ok"), Some(&Json::Bool(true)));
    // the handle-based answer equals the inline answer
    let (inline, _) = engine.handle_line(&instance_line("cp", None, &cell));
    assert_eq!(
        cp.get("length").and_then(Json::as_f64),
        inline.get("length").and_then(Json::as_f64)
    );
    assert_eq!(inline.get("cached"), Some(&Json::Bool(true)));
}

#[test]
fn platform_mix_interns_one_ctx_per_platform() {
    // Six instances round-robined over two platforms (the loadgen
    // --platform-mix shape): the engine must build communication panels
    // exactly twice — once per distinct platform — and serve every other
    // submit from the interned context. Schedule-by-handle traffic never
    // touches the panel cache at all.
    let engine = Engine::with_defaults();
    let mut ids = Vec::new();
    for i in 0..6u64 {
        let mut cell = smoke_cell();
        cell.index = i;
        let (_default_plat, inst) = build_instance(&cell);
        let platform = Platform::uniform(inst.p(), 1.0 + (i % 2) as f64, 0.0);
        let line = format!(
            r#"{{"op":"submit","instance":{},"platform":{}}}"#,
            io::instance_to_json(&inst).to_string(),
            io::platform_to_json(&platform).to_string()
        );
        let (resp, _) = engine.handle_line(&line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "submit {i}");
        ids.push(resp.get("id").and_then(Json::as_str).unwrap().to_string());
    }
    for id in &ids {
        let (resp, _) = engine
            .handle_line(&format!(r#"{{"op":"schedule","algorithm":"CEFT-CPOP","id":"{id}"}}"#));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }
    let (stats, _) = engine.handle_line(r#"{"op":"stats"}"#);
    let panel = stats.get("panel_cache").expect("stats carry a panel_cache section");
    let get = |k: &str| panel.get(k).and_then(Json::as_f64).unwrap();
    assert_eq!(get("len"), 2.0, "one live ctx per distinct platform");
    assert_eq!(get("misses"), 2.0, "panels computed once per platform");
    assert_eq!(get("hits"), 4.0, "remaining submits reuse interned panels");
    assert_eq!(get("insertions"), 2.0);
    // per-platform workspace pools are reported, one entry per ctx
    let per_ctx = stats
        .get("workspaces")
        .and_then(|w| w.get("per_ctx"))
        .and_then(Json::as_arr)
        .expect("workspaces carry a per_ctx breakdown");
    assert_eq!(per_ctx.len(), 2);
    // the memo caches are sharded per platform ctx: two live shards, and
    // the cross-request batching counters are present (zero on this
    // schedule-only, serially-driven mix)
    let sched_cache = stats.get("sched_cache").expect("sched_cache section");
    assert_eq!(sched_cache.get("shards").and_then(Json::as_f64), Some(2.0));
    let cp_cache = stats.get("cp_cache").expect("cp_cache section");
    assert_eq!(
        cp_cache.get("batched_requests").and_then(Json::as_f64),
        Some(0.0)
    );
    assert_eq!(cp_cache.get("batch_width").and_then(Json::as_f64), Some(0.0));
    // clear drops the contexts too; the next submit re-interns
    let (cleared, _) = engine.handle_line(r#"{"op":"clear"}"#);
    assert_eq!(cleared.get("ok"), Some(&Json::Bool(true)));
    let (stats, _) = engine.handle_line(r#"{"op":"stats"}"#);
    assert_eq!(
        stats
            .get("panel_cache")
            .and_then(|p| p.get("len"))
            .and_then(Json::as_f64),
        Some(0.0)
    );
}

fn roundtrip(stream: &mut TcpStream, line: &str) -> Json {
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim_end()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
}

fn connect(addr: SocketAddr) -> TcpStream {
    TcpStream::connect(addr).expect("connect to test server")
}

#[test]
fn tcp_server_smoke_test_with_concurrent_clients() {
    let engine = Arc::new(Engine::new(EngineConfig {
        cache_capacity: 256,
        threads: 2,
        ..EngineConfig::default()
    }));
    let server = Server::bind(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.run());

    // one client submits, everyone else hammers by handle and inline
    let cell = smoke_cell();
    let id = {
        let mut stream = connect(addr);
        let resp = roundtrip(&mut stream, &instance_line("submit", None, &cell));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        resp.get("id").and_then(Json::as_str).unwrap().to_string()
    };

    let mut clients = Vec::new();
    for c in 0..4 {
        let id = id.clone();
        clients.push(std::thread::spawn(move || {
            let mut stream = connect(addr);
            let algo = Algorithm::ALL[c % Algorithm::ALL.len()].name();
            let mut expected: Option<f64> = None;
            for round in 0..5 {
                let resp = roundtrip(
                    &mut stream,
                    &format!(r#"{{"op":"schedule","algorithm":"{algo}","id":"{id}"}}"#),
                );
                assert_eq!(
                    resp.get("ok"),
                    Some(&Json::Bool(true)),
                    "client {c} round {round}: {resp:?}"
                );
                let m = resp.get("makespan").and_then(Json::as_f64).unwrap();
                match expected {
                    None => expected = Some(m),
                    Some(e) => assert_eq!(m, e, "client {c} saw a different makespan"),
                }
                let pong = roundtrip(&mut stream, r#"{"op":"ping"}"#);
                assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
            }
        }));
    }
    for c in clients {
        c.join().expect("client thread");
    }

    // stats over TCP show cache activity from the clients
    {
        let mut stream = connect(addr);
        let stats = roundtrip(&mut stream, r#"{"op":"stats"}"#);
        let sched = stats.get("sched_cache").unwrap();
        assert!(sched.get("hits").and_then(Json::as_f64).unwrap() >= 1.0);
    }

    // graceful shutdown unblocks the accept loop
    {
        let mut stream = connect(addr);
        let resp = roundtrip(&mut stream, r#"{"op":"shutdown"}"#);
        assert_eq!(resp.get("shutting_down"), Some(&Json::Bool(true)));
    }
    server_thread
        .join()
        .expect("server thread")
        .expect("server run");
}

/// Like [`roundtrip`] but surfaces a server-side connection drop (an empty
/// read) as `None` instead of panicking — what a retrying client observes.
fn try_roundtrip(stream: &mut TcpStream, line: &str) -> Option<Json> {
    writeln!(stream, "{line}").ok()?;
    stream.flush().ok()?;
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut resp = String::new();
    let n = reader.read_line(&mut resp).ok()?;
    if n == 0 {
        return None;
    }
    Some(Json::parse(resp.trim_end()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}")))
}

fn shutdown_server(
    addr: SocketAddr,
    server_thread: std::thread::JoinHandle<std::io::Result<()>>,
) {
    let mut stream = connect(addr);
    let resp = roundtrip(&mut stream, r#"{"op":"shutdown"}"#);
    assert_eq!(resp.get("shutting_down"), Some(&Json::Bool(true)));
    server_thread
        .join()
        .expect("server thread")
        .expect("server run");
}

#[test]
fn connection_survives_injected_kernel_panic_and_recovers() {
    // One injected kernel panic: the very first gathered sweep dies. The
    // connection that asked must get a structured internal_panic — not a
    // dead socket, not a hang — and the SAME connection's retry must then
    // be served the real answer.
    let engine = Arc::new(Engine::new(EngineConfig {
        cache_capacity: 256,
        threads: 2,
        fault: Some(FaultPlan::parse("seed=0,kernel_panic=1x1").unwrap()),
        ..EngineConfig::default()
    }));
    let server = Server::bind(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.run());

    let mut stream = connect(addr);
    let cell = smoke_cell();
    let submitted = roundtrip(&mut stream, &instance_line("submit", None, &cell));
    let id = submitted.get("id").and_then(Json::as_str).unwrap().to_string();
    let cp_line = format!(r#"{{"op":"cp","id":"{id}"}}"#);

    let poisoned = roundtrip(&mut stream, &cp_line);
    assert_eq!(poisoned.get("ok"), Some(&Json::Bool(false)), "{poisoned:?}");
    assert_eq!(
        poisoned.get("error").and_then(Json::as_str),
        Some("internal_panic")
    );
    assert!(
        poisoned
            .get("detail")
            .and_then(Json::as_str)
            .map_or(false, |d| d.contains("injected fault")),
        "the caught panic's message must reach the client: {poisoned:?}"
    );
    assert!(poisoned.get("retry_after_ms").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0);

    // same connection, same request: the plan's cap is spent, so the retry
    // computes for real
    let served = roundtrip(&mut stream, &cp_line);
    assert_eq!(served.get("ok"), Some(&Json::Bool(true)), "{served:?}");
    assert!(served.get("length").and_then(Json::as_f64).unwrap() > 0.0);

    let stats = roundtrip(&mut stream, r#"{"op":"stats"}"#);
    let resil = stats.get("resilience").expect("stats carry a resilience section");
    assert_eq!(resil.get("panics_caught").and_then(Json::as_f64), Some(1.0));
    assert_eq!(resil.get("fault_plan_armed"), Some(&Json::Bool(true)));

    shutdown_server(addr, server_thread);
}

#[test]
fn conn_drop_fault_closes_cleanly_and_a_reconnect_retry_is_served() {
    // `conn_drop` severs the connection after the work is done but before
    // the reply is written — the crash-at-the-worst-moment shape. The
    // client sees an empty read (never a partial line), reconnects, and
    // the retry is served from cache.
    let engine = Arc::new(Engine::new(EngineConfig {
        cache_capacity: 256,
        threads: 2,
        fault: Some(FaultPlan::parse("seed=0,conn_drop=1x1").unwrap()),
        ..EngineConfig::default()
    }));
    let server = Server::bind(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.run());

    let cell = smoke_cell();
    let line = instance_line("cp", None, &cell);
    let dropped = {
        let mut stream = connect(addr);
        try_roundtrip(&mut stream, &line)
    };
    assert!(dropped.is_none(), "the first reply should have been dropped");

    let mut stream = connect(addr);
    let retried = try_roundtrip(&mut stream, &line).expect("retry after reconnect");
    assert_eq!(retried.get("ok"), Some(&Json::Bool(true)), "{retried:?}");
    // the dropped request still did its work before the injected sever
    assert_eq!(retried.get("cached"), Some(&Json::Bool(true)));

    shutdown_server(addr, server_thread);
}

#[test]
fn protocol_hardening_rejects_hostile_input_without_killing_the_connection() {
    // Hostile bytes on the wire — truncation, pathological nesting, JSON
    // extensions, out-of-domain deadlines — must each produce a structured
    // `ok:false` on a connection that keeps serving. A panic here would
    // kill the connection thread; a hang would kill the client.
    let engine = Arc::new(Engine::new(EngineConfig {
        cache_capacity: 64,
        threads: 2,
        ..EngineConfig::default()
    }));
    let server = Server::bind(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.run());

    let deep_array = format!("{}{}", "[".repeat(300), "]".repeat(300));
    let hostile: Vec<String> = vec![
        // truncated mid-object (a crashed client's final write)
        r#"{"op":"cp","instance":{"n":2,"p":1,"edges"#.to_string(),
        // nesting past the parser's depth limit
        format!(r#"{{"op":"cp","instance":{deep_array}}}"#),
        // JSON "extensions" the codec must refuse, not absorb
        r#"{"op":"cp","deadline_ms":NaN}"#.to_string(),
        // a deadline that parses to f64 infinity
        r#"{"op":"cp","id":"0000000000000001","deadline_ms":1e999}"#.to_string(),
        // negative budget
        r#"{"op":"cp","id":"0000000000000001","deadline_ms":-5}"#.to_string(),
        // structurally valid, semantically absurd
        r#"{"op":"update","id":"0000000000000001","edits":[{"edit":"task_cost"}]}"#.to_string(),
    ];
    let mut stream = connect(addr);
    for bad in &hostile {
        let resp = try_roundtrip(&mut stream, bad)
            .unwrap_or_else(|| panic!("connection died on hostile input: {bad}"));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "accepted: {bad}");
        assert!(
            resp.get("error").and_then(Json::as_str).is_some(),
            "no structured error for: {bad}"
        );
    }
    // the same connection still serves real work
    let pong = roundtrip(&mut stream, r#"{"op":"ping"}"#);
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
    let served = roundtrip(&mut stream, &instance_line("cp", None, &smoke_cell()));
    assert_eq!(served.get("ok"), Some(&Json::Bool(true)));

    shutdown_server(addr, server_thread);
}

#[test]
fn deadline_and_retry_after_surface_over_tcp() {
    // End-to-end deadline shape: an expired budget on an uncached instance
    // is refused with deadline_exceeded + retry_after_ms, the connection
    // survives, and the identical undeadlined request is then served.
    let engine = Arc::new(Engine::new(EngineConfig {
        cache_capacity: 64,
        threads: 2,
        ..EngineConfig::default()
    }));
    let server = Server::bind(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.run());

    let mut stream = connect(addr);
    let cell = smoke_cell();
    let submitted = roundtrip(&mut stream, &instance_line("submit", None, &cell));
    let id = submitted.get("id").and_then(Json::as_str).unwrap().to_string();

    let refused = roundtrip(
        &mut stream,
        &format!(r#"{{"op":"cp","id":"{id}","deadline_ms":0}}"#),
    );
    assert_eq!(refused.get("ok"), Some(&Json::Bool(false)), "{refused:?}");
    assert_eq!(
        refused.get("error").and_then(Json::as_str),
        Some("deadline_exceeded")
    );
    assert!(refused.get("retry_after_ms").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0);

    let served = roundtrip(&mut stream, &format!(r#"{{"op":"cp","id":"{id}"}}"#));
    assert_eq!(served.get("ok"), Some(&Json::Bool(true)), "{served:?}");
    // and once cached, even an expired budget is served — a hit costs
    // nothing, so shedding it would only destroy availability
    let hit = roundtrip(
        &mut stream,
        &format!(r#"{{"op":"cp","id":"{id}","deadline_ms":0}}"#),
    );
    assert_eq!(hit.get("ok"), Some(&Json::Bool(true)), "{hit:?}");
    assert_eq!(hit.get("cached"), Some(&Json::Bool(true)));

    let stats = roundtrip(&mut stream, r#"{"op":"stats"}"#);
    let resil = stats.get("resilience").expect("resilience section");
    assert_eq!(resil.get("deadline_expired").and_then(Json::as_f64), Some(1.0));

    shutdown_server(addr, server_thread);
}
