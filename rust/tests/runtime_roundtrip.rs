//! Integration tests for the AOT boundary: JAX/Pallas → HLO text → PJRT →
//! rust. These tests *require* `make artifacts` to have run; they are
//! skipped (with a note) when the artifacts are missing so `cargo test`
//! stays green on a fresh checkout.

use ceft::cp::ceft::find_critical_path;
use ceft::graph::generator::{generate, RggParams};
use ceft::platform::{CostModel, Platform};
use ceft::runtime::{relax_batch_reference, AcceleratedCeft, PjrtRuntime, BATCH, CLASS_SIZES};
use ceft::util::rng::Xoshiro256;

fn runtime_or_skip() -> Option<PjrtRuntime> {
    let rt = match PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT client unavailable: {e}");
            return None;
        }
    };
    if !rt.has_artifact(8) {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(rt)
}

#[test]
fn pjrt_relaxation_matches_rust_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256::new(42);
    for &p in &CLASS_SIZES {
        if !rt.has_artifact(p) {
            continue;
        }
        let f: Vec<f32> = (0..BATCH * p)
            .map(|_| rng.uniform(0.0, 1000.0) as f32)
            .collect();
        let data: Vec<f32> = (0..BATCH).map(|_| rng.uniform(0.0, 100.0) as f32).collect();
        let l: Vec<f32> = (0..p).map(|_| rng.uniform(0.0, 2.0) as f32).collect();
        let mut invbw: Vec<f32> = (0..p * p)
            .map(|_| rng.uniform(0.1, 3.0) as f32)
            .collect();
        for i in 0..p {
            invbw[i * p + i] = 0.0;
        }
        let comp: Vec<f32> = (0..BATCH * p)
            .map(|_| rng.uniform(0.5, 50.0) as f32)
            .collect();
        let got = rt.relax_batch(p, &f, &data, &l, &invbw, &comp).unwrap();
        let expect = relax_batch_reference(p, &f, &data, &l, &invbw, &comp);
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!(
                (g - e).abs() <= 1e-3 * e.abs().max(1.0),
                "p={p} cell {i}: pjrt {g} vs rust {e}"
            );
        }
    }
}

#[test]
fn accelerated_ceft_agrees_with_pure_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let acc = AcceleratedCeft::new(rt);
    for &(n, p, ccr) in &[(64usize, 2usize, 0.1), (128, 8, 1.0), (256, 4, 10.0)] {
        if !acc.supports(p) {
            continue;
        }
        let plat = Platform::uniform(p, 1.0, 0.5);
        let inst = generate(
            &RggParams {
                n,
                out_degree: 4,
                ccr,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.75 },
            &plat,
            n as u64,
        );
        let cpu = find_critical_path(inst.bind(&plat));
        let accel = acc.find_critical_path(inst.bind(&plat)).unwrap();
        let rel = (cpu.length - accel.length).abs() / cpu.length;
        assert!(rel < 1e-4, "n={n} p={p}: rel diff {rel}");
        assert_eq!(cpu.tasks(), accel.tasks(), "paths diverged n={n} p={p}");
    }
}

#[test]
fn accelerated_table_matches_f64_table_everywhere() {
    let Some(rt) = runtime_or_skip() else { return };
    let acc = AcceleratedCeft::new(rt);
    let p = 8;
    if !acc.supports(p) {
        return;
    }
    let plat = Platform::uniform(p, 2.0, 0.0);
    let inst = generate(
        &RggParams {
            n: 200,
            out_degree: 3,
            ccr: 1.0,
            alpha: 0.5,
            beta_pct: 50.0,
            gamma: 0.25,
        },
        &CostModel::Classic { beta: 0.5 },
        &plat,
        9,
    );
    let accel = acc.ceft_table(inst.bind(&plat)).unwrap();
    let exact = ceft::cp::ceft::ceft_table(inst.bind(&plat));
    for t in 0..200 {
        for j in 0..p {
            let a = accel.get(t, j);
            let e = exact.get(t, j);
            assert!(
                (a - e).abs() <= 1e-3 * e.abs().max(1.0),
                "cell ({t},{j}): accel {a} vs exact {e}"
            );
        }
    }
}
