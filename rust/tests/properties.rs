//! Property-based tests over randomly generated instances, via the
//! in-repo property harness (`ceft::util::prop`). Each property runs
//! `CEFT_PROP_CASES` (default 64) randomized cases with reproducible seeds.
//!
//! The bit-identity block at the bottom is the contract of the model-layer
//! refactor: the blocked min-plus CEFT kernel must reproduce the scalar
//! reference recurrence bit for bit (values, backpointers, tie-breaking),
//! and every registered algorithm dispatched through `InstanceRef` must be
//! bit-identical to the pre-refactor compositional pipeline rebuilt from
//! the scalar DP and the public rank/list primitives.

use ceft::cp::ceft::simd::KernelDispatch;
use ceft::cp::ceft::{
    ceft_table, ceft_table_batched_into, ceft_table_batched_into_dispatched,
    ceft_table_delta_into_dispatched, ceft_table_into, ceft_table_into_dispatched,
    ceft_table_rev_into, ceft_table_rev_into_dispatched, ceft_table_rev_scalar_into,
    ceft_table_rev_with, ceft_table_scalar, ceft_table_scalar_into, ceft_table_with,
    critical_path_from_table, find_ceft_tables_gathered_delta_dispatched,
    find_ceft_tables_gathered_dispatched, find_critical_path, find_critical_path_with,
    find_critical_paths_gathered_dispatched, slack_from_table_with, DeltaPlan,
};
use ceft::graph::edit::{apply_edits, GraphEdit};
use ceft::cp::cpmin::cp_min_cost;
use ceft::cp::minexec::min_exec_critical_path;
use ceft::cp::ranks::{
    cpop_cp_from_priorities, cpop_cp_processor, cpop_priorities_into, rank_downward_into,
    rank_upward_into,
};
use ceft::cp::ceft::sp::{ceft_table_sp_into_dispatched, ceft_table_sp_rev_into_dispatched};
use ceft::cp::workspace::Workspace;
use ceft::graph::generator::{generate, generate_fork_join, generate_pipeline, Instance, RggParams};
use ceft::graph::shape::{self, ShapeClass};
use ceft::graph::TaskGraph;
use ceft::model::{CostMatrix, InstanceRef, PlatformCtx};
use ceft::platform::{CostModel, Platform};
use ceft::sched::{
    ceft_cpop::CeftCpop, ceft_heft::CeftHeftUp, cpop::Cpop, heft::Heft, list_schedule_with,
    Algorithm, PlacementWs, Schedule, Scheduler, TableDir,
};
use ceft::util::prop::{check_property, default_cases};
use ceft::util::rng::Xoshiro256;
use std::sync::Arc;

/// Random instance generator spanning both cost models, platform comm
/// heterogeneity, all sizes the unit tests don't reach.
fn arb_instance(rng: &mut Xoshiro256) -> (Instance, Platform, u64) {
    let n = rng.range_inclusive(2, 120);
    let p = *rng.choose(&[1usize, 2, 3, 4, 8, 16]);
    let two_weight = rng.chance(0.4) && p >= 2;
    let seed = rng.next_u64();
    let plat = if two_weight {
        Platform::two_weight(p, rng.uniform(0.1, 0.9), rng, 1.0, 0.0)
    } else if rng.chance(0.5) {
        Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 2.0))
    } else {
        Platform::random_links(p, rng, 0.2, 5.0, 0.0, 2.0)
    };
    let model = if two_weight {
        CostModel::two_weight_medium(0.5)
    } else {
        CostModel::Classic {
            beta: rng.uniform(0.0, 1.0),
        }
    };
    let params = RggParams {
        n,
        out_degree: rng.range_inclusive(1, 6),
        ccr: *rng.choose(&[0.001, 0.1, 1.0, 10.0]),
        alpha: rng.uniform(0.1, 1.0),
        beta_pct: rng.uniform(0.0, 100.0),
        gamma: rng.uniform(0.0, 1.0),
    };
    let inst = generate(&params, &model, &plat, seed);
    (inst, plat, seed)
}

#[test]
fn prop_every_schedule_is_valid() {
    check_property(
        "every schedule valid",
        default_cases(),
        0xCEF7_0001,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            let algos: [&dyn Scheduler; 4] = [&Cpop, &Heft, &CeftCpop, &CeftHeftUp];
            for a in algos {
                let s = a.schedule(iref);
                s.validate(iref)
                    .map_err(|e| format!("{} (seed {seed}): {e}", a.name()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cpl_bounds() {
    check_property(
        "cp_min <= minexec <= ceft",
        default_cases(),
        0xCEF7_0002,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let iref = inst.bind(plat);
            let cpmin = cp_min_cost(iref);
            let me = min_exec_critical_path(iref, false);
            let cp = find_critical_path(iref);
            if cpmin > me.length + 1e-9 {
                return Err(format!("cp_min {cpmin} > minexec {}", me.length));
            }
            if me.length > cp.length + 1e-9 {
                return Err(format!("minexec {} > ceft {}", me.length, cp.length));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_makespan_dominates_cpmin_and_slr_ge_one() {
    check_property(
        "makespan >= cp_min, slr >= 1",
        default_cases(),
        0xCEF7_0003,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let iref = inst.bind(plat);
            let cpmin = cp_min_cost(iref);
            for a in [&Cpop as &dyn Scheduler, &Heft, &CeftCpop] {
                let m = a.schedule(iref).makespan();
                if m + 1e-6 < cpmin {
                    return Err(format!("{}: makespan {m} < cp_min {cpmin}", a.name()));
                }
                let slr = ceft::metrics::slr(iref, m);
                if slr < 1.0 - 1e-9 {
                    return Err(format!("{}: slr {slr} < 1", a.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ceft_path_structure() {
    check_property(
        "ceft path connected source->sink with consistent table",
        default_cases(),
        0xCEF7_0004,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let iref = inst.bind(plat);
            let cp = find_critical_path(iref);
            if cp.path.is_empty() {
                return Err("empty path".into());
            }
            if inst.graph.in_degree(cp.path[0].task) != 0 {
                return Err("path does not start at a source".into());
            }
            if inst.graph.out_degree(cp.path.last().unwrap().task) != 0 {
                return Err("path does not end at a sink".into());
            }
            for w in cp.path.windows(2) {
                if !inst
                    .graph
                    .succs(w[0].task)
                    .iter()
                    .any(|&(d, _)| d == w[1].task)
                {
                    return Err(format!("missing edge {} -> {}", w[0].task, w[1].task));
                }
            }
            // length matches the table cell of the final step
            let table = ceft_table(iref);
            let last = cp.path.last().unwrap();
            let cell = table.get(last.task, last.class);
            if (cell - cp.length).abs() > 1e-9 {
                return Err(format!("table cell {cell} != length {}", cp.length));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ceft_monotone_under_cost_increase() {
    // raising a single task's execution cost can never shorten the CPL
    check_property(
        "ceft monotone in comp costs",
        default_cases(),
        0xCEF7_0005,
        |rng| {
            let (inst, plat, seed) = arb_instance(rng);
            let t = rng.below(inst.graph.num_tasks());
            let bump = rng.uniform(1.0, 100.0);
            (inst, plat, seed, t, bump)
        },
        |(inst, plat, _, t, bump)| {
            let p = plat.num_classes();
            let before = find_critical_path(inst.bind(plat)).length;
            let mut raised = inst.comp.as_slice().to_vec();
            for j in 0..p {
                raised[t * p + j] += bump;
            }
            let comp2 = CostMatrix::new(p, raised);
            let after =
                find_critical_path(InstanceRef::new(&inst.graph, plat, &comp2)).length;
            if after + 1e-9 < before {
                return Err(format!("CPL dropped {before} -> {after} after raising task {t}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ceft_scale_invariance() {
    // multiplying all costs (comp and comm payloads) by s scales CPL by s
    check_property(
        "ceft scale invariance",
        default_cases() / 2,
        0xCEF7_0006,
        |rng| {
            let (inst, plat, seed) = arb_instance(rng);
            (inst, plat, seed, rng.uniform(0.5, 8.0))
        },
        |(inst, plat, _, s)| {
            let before = find_critical_path(inst.bind(plat)).length;
            let comp2 = CostMatrix::new(
                plat.num_classes(),
                inst.comp.as_slice().iter().map(|c| c * s).collect(),
            );
            let edges2: Vec<(usize, usize, f64)> = inst
                .graph
                .edges()
                .iter()
                .map(|e| (e.src, e.dst, e.data * s))
                .collect();
            // scale startup too: rebuilding a platform clone is not exposed,
            // so only run this property on zero-startup platforms
            if (0..plat.num_classes()).any(|j| plat.startup(j) != 0.0) {
                return Ok(()); // skip non-zero-startup draws
            }
            let g2 = TaskGraph::from_edges(inst.graph.num_tasks(), &edges2);
            let after = find_critical_path(InstanceRef::new(&g2, plat, &comp2)).length;
            let rel = (after - s * before).abs() / (s * before).max(1e-12);
            if rel > 1e-9 {
                return Err(format!("scaled CPL {after} != {s} * {before}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pinned_tasks_respected() {
    check_property(
        "ceft-cpop pins its critical path",
        default_cases() / 2,
        0xCEF7_0007,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let iref = inst.bind(plat);
            let cp = find_critical_path(iref);
            let s = CeftCpop.schedule(iref);
            for step in &cp.path {
                if s.assignments[step.task].proc != step.class {
                    return Err(format!(
                        "task {} scheduled on {} instead of pinned {}",
                        step.task, s.assignments[step.task].proc, step.class
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transposed_ceft_symmetric_on_chains() {
    // On a *chain* (single path) with symmetric zero-startup comm, the CPL
    // is direction-invariant: reversing the optimal assignment of the
    // reversed chain gives the same cost. (On general DAGs this is NOT a
    // theorem — the DP anchors its final `min` at the sink's class, and
    // transposition moves that anchor to the source.)
    check_property(
        "chain CPL(G) == CPL(G^T) under symmetric comm",
        default_cases() / 2,
        0xCEF7_0008,
        |rng| {
            let n = rng.range_inclusive(2, 60);
            let p = *rng.choose(&[2usize, 4, 8]);
            let plat = Platform::uniform(p, rng.uniform(0.2, 5.0), 0.0);
            let edges: Vec<(usize, usize, f64)> = (0..n - 1)
                .map(|i| (i, i + 1, rng.uniform(0.0, 50.0)))
                .collect();
            let g = TaskGraph::from_edges(n, &edges);
            let comp =
                CostMatrix::new(p, (0..n * p).map(|_| rng.uniform(1.0, 40.0)).collect());
            (g, plat, comp)
        },
        |(g, plat, comp)| {
            let fwd = find_critical_path(InstanceRef::new(g, plat, comp)).length;
            let gt = g.transpose();
            let bwd = find_critical_path(InstanceRef::new(&gt, plat, comp)).length;
            if (fwd - bwd).abs() > 1e-6 * fwd.max(1.0) {
                return Err(format!("fwd {fwd} != bwd {bwd}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Bit-identity properties of the model-layer refactor: kernel vs scalar DP,
// and registry dispatch vs the compositional scalar-reference pipeline.
// ---------------------------------------------------------------------------

/// Per-task row minima — the exact fold `sched::ceft_heft` applies to the
/// DP table when building CEFT-HEFT priorities.
fn row_mins(table: &[f64], v: usize, p: usize) -> Vec<f64> {
    (0..v)
        .map(|t| {
            table[t * p..(t + 1) * p]
                .iter()
                .fold(f64::INFINITY, |a, &b| a.min(b))
        })
        .collect()
}

fn schedules_identical(a: &Schedule, b: &Schedule) -> bool {
    a.p == b.p && a.assignments == b.assignments
}

/// Rebuild each registered algorithm from the public rank/list primitives
/// with every CEFT table produced by the **scalar** reference DP, and
/// return its schedule. What this proves differs by algorithm: for the
/// CEFT-based three (CEFT-CPOP, CEFT-HEFT-UP/DOWN) the reference forces
/// the scalar DP where the scheduler runs the kernel, so equality is a
/// genuine kernel-vs-scalar check; for the mean-value three (CPOP, HEFT,
/// HEFT-DOWN) no CEFT table is involved and the reference reuses the same
/// rank/list primitives the scheduler calls — there equality checks only
/// that registry dispatch and the `InstanceRef` plumbing add nothing (a
/// shared regression in the primitives themselves would move both sides).
fn scalar_reference_schedule(algo: Algorithm, inst: InstanceRef) -> Schedule {
    let mut ws = Workspace::new();
    match algo {
        Algorithm::Cpop => {
            cpop_priorities_into(&mut ws, inst);
            cpop_cp_from_priorities(inst.graph, &ws.prio, &mut ws.cp_tasks);
            let p_cp = cpop_cp_processor(&ws.cp_tasks, inst.costs);
            ws.pins.clear();
            ws.pins.resize(inst.n(), None);
            for &t in &ws.cp_tasks {
                ws.pins[t] = Some(p_cp);
            }
            list_schedule_with(&mut ws, inst, PlacementWs::Pinned)
        }
        Algorithm::Heft => {
            rank_upward_into(inst, &mut ws.prio);
            list_schedule_with(&mut ws, inst, PlacementWs::MinEft)
        }
        Algorithm::HeftDown => {
            rank_downward_into(inst, &mut ws.down);
            ws.prio.clear();
            ws.prio.extend(ws.down.iter().map(|d| -d));
            list_schedule_with(&mut ws, inst, PlacementWs::MinEft)
        }
        Algorithm::CeftCpop => {
            let t = ceft_table_scalar(inst);
            let cp = critical_path_from_table(inst.graph, &t);
            cpop_priorities_into(&mut ws, inst);
            cp.fill_assignment_dense(inst.n(), &mut ws.pins);
            list_schedule_with(&mut ws, inst, PlacementWs::Pinned)
        }
        Algorithm::CeftHeftUp => {
            ceft_table_rev_scalar_into(&mut ws, inst);
            let mins = row_mins(&ws.table, inst.n(), inst.p());
            ws.prio.clear();
            ws.prio.extend_from_slice(&mins);
            list_schedule_with(&mut ws, inst, PlacementWs::MinEft)
        }
        Algorithm::CeftHeftDown => {
            ceft_table_scalar_into(&mut ws, inst);
            let mins = row_mins(&ws.table, inst.n(), inst.p());
            ws.prio.clear();
            ws.prio.extend(mins.iter().map(|d| -d));
            list_schedule_with(&mut ws, inst, PlacementWs::MinEft)
        }
    }
}

#[test]
fn prop_kernel_dp_bit_identical_to_scalar() {
    check_property(
        "blocked min-plus kernel == scalar DP (values + backpointers)",
        default_cases(),
        0xCEF7_0020,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            let mut kw = Workspace::new();
            let mut sw = Workspace::new();
            ceft_table_into(&mut kw, iref);
            ceft_table_scalar_into(&mut sw, iref);
            if kw.table != sw.table {
                return Err(format!("forward tables diverged (seed {seed})"));
            }
            if kw.backptr != sw.backptr {
                return Err(format!("forward backpointers diverged (seed {seed})"));
            }
            ceft_table_rev_into(&mut kw, iref);
            ceft_table_rev_scalar_into(&mut sw, iref);
            if kw.table != sw.table {
                return Err(format!("reverse tables diverged (seed {seed})"));
            }
            if kw.backptr != sw.backptr {
                return Err(format!("reverse backpointers diverged (seed {seed})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_algorithms_bit_identical_to_scalar_reference() {
    // Every registered algorithm, dispatched through InstanceRef, must
    // equal its compositional reference pipeline (see
    // `scalar_reference_schedule` for what that proves per algorithm —
    // a true kernel-vs-scalar check for the CEFT-based three, a
    // dispatch/plumbing check for the mean-value three).
    check_property(
        "registry dispatch == scalar compositional reference (all six)",
        default_cases() / 2,
        0xCEF7_0021,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            for algo in Algorithm::ALL {
                let via_registry = algo.schedule(iref);
                let reference = scalar_reference_schedule(algo, iref);
                if !schedules_identical(&via_registry, &reference) {
                    return Err(format!(
                        "{} diverged from the scalar reference (seed {seed})",
                        algo.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_kernel_bit_identical_to_scalar() {
    // The batched min-plus matrix-matrix DP must reproduce the scalar
    // recurrence bit for bit — values, backpointers, tie-breaking — for
    // every chunk size, including B == 1 (degenerate matrix-vector), sizes
    // straddling KERNEL_BLOCK (7, 8, 9), and P == 1 platforms
    // (arb_instance draws them). The ctx-resident fused kernel is held to
    // the same bar, and one reused workspace across all runs doubles as a
    // no-state-leak check.
    check_property(
        "batched kernel == scalar DP for B in {1,2,7,8,9}",
        default_cases() / 2,
        0xCEF7_0023,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let mut sw = Workspace::new();
            ceft_table_scalar_into(&mut sw, inst.bind(plat));
            let ctx = PlatformCtx::new(plat.clone());
            let bound = inst.bind_ctx(&ctx);
            let mut bw = Workspace::new();
            for &b in &[1usize, 2, 7, 8, 9] {
                ceft_table_batched_into(&mut bw, bound, b);
                if bw.table != sw.table {
                    return Err(format!("batched values diverged at B={b} (seed {seed})"));
                }
                if bw.backptr != sw.backptr {
                    return Err(format!(
                        "batched backpointers diverged at B={b} (seed {seed})"
                    ));
                }
            }
            ceft_table_into(&mut bw, bound);
            if bw.table != sw.table || bw.backptr != sw.backptr {
                return Err(format!(
                    "ctx-resident fused kernel diverged from scalar (seed {seed})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_kernel_bit_identical_to_scalar() {
    // The hand-vectorised lanes must reproduce the scalar-recurrence
    // oracle bit for bit — values, backpointers, tie-breaking — across the
    // class counts the lane structure cares about: below one lane (1, 2,
    // 3), exactly one lane (4), lane + tail (5, 7, 9), whole lanes (8,
    // 16). Platforms include nonzero startup and heterogeneous links, and
    // the ctx-bound runs exercise the resident panels' 0/+inf diagonal
    // (`data / +inf == +0.0`) through both the fused and the batched
    // matrix-matrix kernel, plus the gathered multi-instance sweep.
    check_property(
        "SIMD lanes == scalar oracle over P in {1,2,3,4,5,7,8,9,16}",
        default_cases(),
        0xCEF7_0025,
        |rng| {
            let p = *rng.choose(&[1usize, 2, 3, 4, 5, 7, 8, 9, 16]);
            let plat = if rng.chance(0.5) {
                Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 2.0))
            } else {
                Platform::random_links(p, rng, 0.2, 5.0, 0.0, 2.0)
            };
            let params = RggParams {
                n: rng.range_inclusive(2, 100),
                out_degree: rng.range_inclusive(1, 5),
                ccr: *rng.choose(&[0.001, 1.0, 10.0]),
                alpha: rng.uniform(0.1, 1.0),
                beta_pct: rng.uniform(0.0, 100.0),
                gamma: rng.uniform(0.0, 1.0),
            };
            let inst = generate(
                &params,
                &CostModel::Classic { beta: 0.5 },
                &plat,
                rng.next_u64(),
            );
            (inst, plat)
        },
        |(inst, plat)| {
            let mut sw = Workspace::new();
            let mut vw = Workspace::new();
            // fused kernel, workspace-local panels, both orientations
            ceft_table_scalar_into(&mut sw, inst.bind(plat));
            ceft_table_into_dispatched(&mut vw, inst.bind(plat), KernelDispatch::Simd);
            if vw.table != sw.table {
                return Err("forward SIMD values diverged".into());
            }
            if vw.backptr != sw.backptr {
                return Err("forward SIMD backpointers diverged".into());
            }
            ceft_table_rev_scalar_into(&mut sw, inst.bind(plat));
            ceft_table_rev_into_dispatched(&mut vw, inst.bind(plat), KernelDispatch::Simd);
            if vw.table != sw.table {
                return Err("reverse SIMD values diverged".into());
            }
            if vw.backptr != sw.backptr {
                return Err("reverse SIMD backpointers diverged".into());
            }
            // ctx-resident panels: fused + batched under pinned SIMD
            let ctx = PlatformCtx::new(plat.clone());
            ceft_table_scalar_into(&mut sw, inst.bind(plat));
            ceft_table_into_dispatched(&mut vw, inst.bind_ctx(&ctx), KernelDispatch::Simd);
            if vw.table != sw.table || vw.backptr != sw.backptr {
                return Err("ctx-resident SIMD kernel diverged".into());
            }
            for &b in &[1usize, 5, 8] {
                ceft_table_batched_into_dispatched(
                    &mut vw,
                    inst.bind_ctx(&ctx),
                    b,
                    KernelDispatch::Simd,
                );
                if vw.table != sw.table || vw.backptr != sw.backptr {
                    return Err(format!("batched SIMD kernel diverged at B={b}"));
                }
            }
            // the gathered multi-instance sweep (instance twice in one
            // window exercises cross-instance row gathering)
            let bound = [inst.bind_ctx(&ctx), inst.bind_ctx(&ctx)];
            let serial = find_critical_path(inst.bind(plat));
            for dispatch in [KernelDispatch::Simd, KernelDispatch::Scalar] {
                let gathered = find_critical_paths_gathered_dispatched(&ctx, &bound, dispatch);
                if gathered.len() != 2 || gathered[0] != serial || gathered[1] != serial {
                    return Err(format!("gathered sweep diverged under {dispatch:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shared_ctx_two_instances_no_state_leak() {
    // One PlatformCtx serving two different instances (interleaved, one
    // reused workspace) must give each instance exactly the bits a fresh
    // unshared computation gives — ctx reuse shares panels, never DP
    // state. This is the engine's platform-interning contract in miniature.
    check_property(
        "shared ctx serves two instances without leaking state",
        default_cases() / 2,
        0xCEF7_0024,
        |rng| {
            let p = *rng.choose(&[1usize, 2, 4, 8]);
            let plat = if rng.chance(0.5) {
                Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 2.0))
            } else {
                Platform::random_links(p, rng, 0.2, 5.0, 0.0, 2.0)
            };
            let params = |n| RggParams {
                n,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            };
            let big = generate(
                &params(rng.range_inclusive(40, 120)),
                &CostModel::Classic { beta: 0.5 },
                &plat,
                rng.next_u64(),
            );
            let small = generate(
                &params(rng.range_inclusive(2, 30)),
                &CostModel::Classic { beta: 0.5 },
                &plat,
                rng.next_u64(),
            );
            (plat, big, small)
        },
        |(plat, big, small)| {
            let ctx = PlatformCtx::new(plat.clone());
            let mut ws = Workspace::new();
            // interleave big / small / big through one ctx + one workspace
            let big_1 = find_critical_path_with(&mut ws, big.bind_ctx(&ctx));
            let small_shared = find_critical_path_with(&mut ws, small.bind_ctx(&ctx));
            let big_2 = find_critical_path_with(&mut ws, big.bind_ctx(&ctx));
            let big_fresh = find_critical_path(big.bind(plat));
            let small_fresh = find_critical_path(small.bind(plat));
            if big_1 != big_fresh || big_2 != big_fresh {
                return Err("shared ctx changed the big instance's path".into());
            }
            if small_shared != small_fresh {
                return Err("big instance leaked into the small one via the ctx".into());
            }
            // the batched DP through the same shared ctx + workspace too
            let mut sw = Workspace::new();
            for inst in [big, small] {
                ceft_table_batched_into(&mut ws, inst.bind_ctx(&ctx), 7);
                ceft_table_scalar_into(&mut sw, inst.bind(plat));
                if ws.table != sw.table || ws.backptr != sw.backptr {
                    return Err("batched DP diverged under ctx sharing".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_run_with_tables_bit_identical() {
    // The table-borrowing registry entry point (`Algorithm::run_with_tables`)
    // must be bit-identical to plain `run_with` dispatch for every
    // registered algorithm — values AND placements — whether the borrowed
    // table comes from the serial pooled producers (`ceft_table_with` /
    // `ceft_table_rev_with`) or from the gathered multi-instance sweep
    // (`find_ceft_tables_gathered_dispatched`), under either lane
    // implementation. This is the contract the service engine's table memo
    // stands on: a schedule served from a cached or batch-gathered table
    // must be indistinguishable from one that ran its own DP.
    check_property(
        "run_with_tables == run_with for all six (serial + gathered tables)",
        default_cases() / 2,
        0xCEF7_0026,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            let mut ws = Workspace::new();
            let mut tw = Workspace::new();
            let fwd = ceft_table_with(&mut tw, iref);
            let rev = ceft_table_rev_with(&mut tw, iref);
            // gathered sweeps (instance twice in one window, like a batch
            // drain that dedups late): every produced table must equal the
            // serial producer bit for bit before it is allowed to schedule
            let ctx = PlatformCtx::new(plat.clone());
            let bound = [inst.bind_ctx(&ctx), inst.bind_ctx(&ctx)];
            let mut gathered_fwd = Vec::new();
            let mut gathered_rev = Vec::new();
            for dispatch in [KernelDispatch::Simd, KernelDispatch::Scalar] {
                let tf = find_ceft_tables_gathered_dispatched(&ctx, &bound, false, dispatch);
                let tr = find_ceft_tables_gathered_dispatched(&ctx, &bound, true, dispatch);
                for t in &tf {
                    if t.table != fwd.table || t.backptr != fwd.backptr {
                        return Err(format!(
                            "gathered forward table diverged from serial under {dispatch:?} (seed {seed})"
                        ));
                    }
                }
                for t in &tr {
                    if t.table != rev.table || t.backptr != rev.backptr {
                        return Err(format!(
                            "gathered reverse table diverged from serial under {dispatch:?} (seed {seed})"
                        ));
                    }
                }
                gathered_fwd.push(tf.into_iter().next().unwrap());
                gathered_rev.push(tr.into_iter().next().unwrap());
            }
            for algo in Algorithm::ALL {
                let baseline = algo.run_with(&mut ws, iref);
                // no table offered — trivially the plain path
                let none = algo.run_with_tables(&mut ws, iref, None);
                if !schedules_identical(&baseline, &none) {
                    return Err(format!(
                        "{} diverged with table=None (seed {seed})",
                        algo.name()
                    ));
                }
                // a table of the declared orientation; the mean-value three
                // must ignore the offer entirely
                let serial_table = match algo.table_use() {
                    Some(TableDir::Reverse) => &rev,
                    _ => &fwd,
                };
                let via_serial = algo.run_with_tables(&mut ws, iref, Some(serial_table));
                if !schedules_identical(&baseline, &via_serial) {
                    return Err(format!(
                        "{} diverged with a serial table (seed {seed})",
                        algo.name()
                    ));
                }
                if let Some(dir) = algo.table_use() {
                    let pool = match dir {
                        TableDir::Forward => &gathered_fwd,
                        TableDir::Reverse => &gathered_rev,
                    };
                    for t in pool {
                        let via_gathered = algo.run_with_tables(&mut ws, iref, Some(t));
                        if !schedules_identical(&baseline, &via_gathered) {
                            return Err(format!(
                                "{} diverged with a gathered table (seed {seed})",
                                algo.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bit_identity_on_single_chains_and_p1() {
    // The edge cases the acceptance criteria call out explicitly: single
    // chains (every task exactly one parent/child) and single-class
    // platforms, where the kernel's diagonal-panel trick and the P==1
    // zero-mean-comm invariant interact.
    check_property(
        "kernel + registry bit-identity on chains and P == 1",
        default_cases() / 2,
        0xCEF7_0022,
        |rng| {
            let n = rng.range_inclusive(2, 50);
            let p = *rng.choose(&[1usize, 2, 4]);
            let plat = Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 1.0));
            let edges: Vec<(usize, usize, f64)> = (0..n - 1)
                .map(|i| (i, i + 1, rng.uniform(0.0, 50.0)))
                .collect();
            let g = TaskGraph::from_edges(n, &edges);
            let comp =
                CostMatrix::new(p, (0..n * p).map(|_| rng.uniform(1.0, 40.0)).collect());
            (g, plat, comp)
        },
        |(g, plat, comp)| {
            let inst = InstanceRef::new(g, plat, comp);
            let mut kw = Workspace::new();
            let mut sw = Workspace::new();
            ceft_table_into(&mut kw, inst);
            ceft_table_scalar_into(&mut sw, inst);
            if kw.table != sw.table || kw.backptr != sw.backptr {
                return Err("kernel diverged from scalar on a chain".into());
            }
            if plat.num_classes() == 1 {
                // every class choice must be 0 on a single-class platform
                let cp = find_critical_path(inst);
                if !cp.path.iter().all(|s| s.class == 0) {
                    return Err("P == 1 produced a nonzero class".into());
                }
            }
            for algo in Algorithm::ALL {
                let via_registry = algo.schedule(inst);
                let reference = scalar_reference_schedule(algo, inst);
                if !schedules_identical(&via_registry, &reference) {
                    return Err(format!("{} diverged on a chain", algo.name()));
                }
            }
            Ok(())
        },
    );
}

/// Random structure-preserving edit batch over `graph`: everything except
/// `remove_task`, whose id renumbering voids any delta basis (the engine
/// answers those with a full recompute, so there is no delta path to
/// property-test). `add_edge` picks both endpoints from the current
/// topological order (src before dst), so a batch can never create a
/// cycle; `remove_edge`/`edge_cost` draw from the edges still present.
fn arb_edits(rng: &mut Xoshiro256, graph: &ceft::graph::TaskGraph, p: usize) -> Vec<GraphEdit> {
    let n = graph.num_tasks();
    let topo = graph.topo_order();
    let mut removed: Vec<(usize, usize)> = Vec::new();
    let mut edits = Vec::new();
    for _ in 0..rng.range_inclusive(1, 3) {
        let live_edge = |rng: &mut Xoshiro256, removed: &[(usize, usize)]| {
            let live: Vec<_> = graph
                .edges()
                .iter()
                .filter(|e| !removed.contains(&(e.src, e.dst)))
                .collect();
            if live.is_empty() {
                None
            } else {
                Some(**rng.choose(&live))
            }
        };
        match rng.range_inclusive(0, 4) {
            1 => {
                if let Some(e) = live_edge(rng, &removed) {
                    edits.push(GraphEdit::EdgeCost {
                        src: e.src,
                        dst: e.dst,
                        data: rng.uniform(0.0, 5.0),
                    });
                    continue;
                }
            }
            2 if n >= 2 => {
                let i = rng.range_inclusive(0, n - 2);
                let j = rng.range_inclusive(i + 1, n - 1);
                edits.push(GraphEdit::AddEdge {
                    src: topo[i],
                    dst: topo[j],
                    data: rng.uniform(0.0, 5.0),
                });
                continue;
            }
            3 => {
                if let Some(e) = live_edge(rng, &removed) {
                    removed.push((e.src, e.dst));
                    edits.push(GraphEdit::RemoveEdge {
                        src: e.src,
                        dst: e.dst,
                    });
                    continue;
                }
            }
            4 => {
                edits.push(GraphEdit::AddTask {
                    costs: (0..p).map(|_| rng.uniform(0.5, 10.0)).collect(),
                });
                continue;
            }
            _ => {}
        }
        edits.push(GraphEdit::TaskCost {
            task: rng.range_inclusive(0, n - 1),
            costs: (0..p).map(|_| rng.uniform(0.5, 10.0)).collect(),
        });
    }
    edits
}

/// The incremental-recompute contract (EXPERIMENTS.md §Incremental
/// re-scheduling): after one or two rounds of random in-place edits, the
/// delta kernel seeded with the PRE-edit tables and the accumulated dirty
/// set must reproduce a from-scratch solve of the edited instance bit for
/// bit — values and backpointers, forward and reverse orientation, both
/// lane implementations, and through the gathered multi-instance sweep
/// (a delta-planned job sharing its window with a scratch one).
#[test]
fn prop_delta_ceft_bit_identical_to_scratch() {
    check_property(
        "delta ceft == scratch ceft",
        default_cases(),
        0xCEF7_00D1,
        |rng| {
            let (inst, plat, seed) = arb_instance(rng);
            let p = plat.num_classes();
            let g0 = Arc::new(inst.graph.clone());
            let c0 = Arc::new(inst.comp.clone());
            // one or two edit rounds against the same basis: round two
            // accumulates its dirty flags on top of round one's, exactly
            // like the engine when no table of the middle generation was
            // ever computed
            let r1 = apply_edits(&g0, &c0, &arb_edits(rng, &g0, p)).expect("edit round 1");
            let (graph2, costs2, dirty) = if rng.chance(0.5) {
                let r2 =
                    apply_edits(&r1.graph, &r1.costs, &arb_edits(rng, &r1.graph, p))
                        .expect("edit round 2");
                let merged: Vec<bool> = (0..r2.graph.num_tasks())
                    .map(|i| r2.dirty[i] || r1.dirty.get(i).copied().unwrap_or(true))
                    .collect();
                (r2.graph, r2.costs, merged)
            } else {
                (r1.graph.clone(), r1.costs.clone(), r1.dirty)
            };
            (inst, plat, graph2, costs2, dirty, seed)
        },
        |(inst, plat, graph2, costs2, dirty, seed)| {
            let basis_ref = inst.bind(plat);
            let basis_n = inst.graph.num_tasks();
            let basis_topo = inst.graph.topo_order();
            let mut ws = Workspace::new();
            let basis_fwd = ceft_table_with(&mut ws, basis_ref);
            let basis_rev = ceft_table_rev_with(&mut ws, basis_ref);
            let ctx = PlatformCtx::new(plat.clone());
            let eref = ctx.bind(graph2, costs2);
            for rev in [false, true] {
                let basis = if rev { &basis_rev } else { &basis_fwd };
                for dispatch in [KernelDispatch::Scalar, KernelDispatch::Simd] {
                    let mut sw = Workspace::new();
                    if rev {
                        ceft_table_rev_into_dispatched(&mut sw, eref, dispatch);
                    } else {
                        ceft_table_into_dispatched(&mut sw, eref, dispatch);
                    }
                    let plan = DeltaPlan {
                        prev: basis,
                        prev_topo: basis_topo,
                        basis_n,
                        dirty,
                    };
                    let mut dw = Workspace::new();
                    let rows = ceft_table_delta_into_dispatched(&mut dw, eref, &plan, rev, dispatch);
                    if rows > graph2.num_tasks() {
                        return Err(format!(
                            "delta recomputed {rows} rows of {} (seed {seed})",
                            graph2.num_tasks()
                        ));
                    }
                    if dw.table[..] != sw.table[..] || dw.backptr != sw.backptr {
                        return Err(format!(
                            "serial delta diverged from scratch (rev={rev}, {dispatch:?}, seed {seed})"
                        ));
                    }
                    // gathered sweep: the delta-planned job shares its
                    // window with a scratch recompute of the basis
                    let plan = DeltaPlan {
                        prev: basis,
                        prev_topo: basis_topo,
                        basis_n,
                        dirty,
                    };
                    let gref = ctx.bind(&inst.graph, &inst.comp);
                    let out = find_ceft_tables_gathered_delta_dispatched(
                        &ctx,
                        &[eref, gref],
                        rev,
                        &[Some(plan), None],
                        dispatch,
                    );
                    if out[0].0.table[..] != sw.table[..] || out[0].0.backptr != sw.backptr {
                        return Err(format!(
                            "gathered delta diverged from scratch (rev={rev}, {dispatch:?}, seed {seed})"
                        ));
                    }
                    let companion = if rev { &basis_rev } else { &basis_fwd };
                    if out[1].0.table != companion.table {
                        return Err(format!(
                            "gathered scratch companion diverged (rev={rev}, seed {seed})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The slack derivation the `update` skip rule rests on: per-task slack
/// from the forward table is non-negative everywhere, EXACTLY `+0.0` on
/// every task of the realized critical path, and the returned critical
/// length is bit-identical to the table's own sink fold.
#[test]
fn prop_slack_nonnegative_and_zero_on_critical_path() {
    check_property(
        "slack >= 0, == 0 on cp",
        default_cases(),
        0xCEF7_00D2,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            let mut ws = Workspace::new();
            let fwd = ceft_table_with(&mut ws, iref);
            let mut slack = Vec::new();
            let cpl = slack_from_table_with(&mut ws, iref, &fwd, &mut slack);
            let cp = critical_path_from_table(&inst.graph, &fwd.table);
            if cpl != cp.length {
                return Err(format!(
                    "slack CPL {cpl} != table CPL {} (seed {seed})",
                    cp.length
                ));
            }
            if slack.len() != inst.graph.num_tasks() {
                return Err(format!("slack has {} entries (seed {seed})", slack.len()));
            }
            for (t, &s) in slack.iter().enumerate() {
                if !(s >= 0.0) {
                    return Err(format!("slack[{t}] = {s} < 0 (seed {seed})"));
                }
            }
            for step in &cp.path {
                if slack[step.task] != 0.0 {
                    return Err(format!(
                        "cp task {} has slack {} != 0 (seed {seed})",
                        step.task, slack[step.task]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Recursive series/parallel composition over `(src, sink)`: a leaf is a
/// direct edge, a series split routes through a fresh midpoint, a parallel
/// split fans out 2–3 branches each through its own fresh midpoint (so the
/// graph stays simple — no duplicate `(src, sink)` leaves). Every graph
/// this builds is two-terminal series-parallel by construction.
fn build_sp(
    rng: &mut Xoshiro256,
    src: usize,
    sink: usize,
    budget: &mut usize,
    edges: &mut Vec<(usize, usize, f64)>,
    next: &mut usize,
) {
    if *budget == 0 || rng.chance(0.35) {
        edges.push((src, sink, rng.uniform(0.0, 5.0)));
        return;
    }
    *budget -= 1;
    if rng.chance(0.5) {
        let mid = *next;
        *next += 1;
        build_sp(rng, src, mid, budget, edges, next);
        build_sp(rng, mid, sink, budget, edges, next);
    } else {
        for _ in 0..rng.range_inclusive(2, 3) {
            let mid = *next;
            *next += 1;
            build_sp(rng, src, mid, budget, edges, next);
            build_sp(rng, mid, sink, budget, edges, next);
        }
    }
}

/// Random series-parallel instance: the explicit structured families
/// (chain via width-1 fork-join, fork-join, pipeline) plus nested random
/// series/parallel compositions, over varied platforms including P = 1.
fn arb_sp_instance(rng: &mut Xoshiro256) -> (Instance, Platform, u64) {
    let p = *rng.choose(&[1usize, 2, 4, 8]);
    let plat = if rng.chance(0.5) {
        Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 2.0))
    } else {
        Platform::random_links(p, rng, 0.2, 5.0, 0.0, 2.0)
    };
    let model = CostModel::Classic {
        beta: rng.uniform(0.0, 1.0),
    };
    let seed = rng.next_u64();
    let ccr = *rng.choose(&[0.1, 1.0, 10.0]);
    let beta_pct = rng.uniform(0.0, 100.0);
    let inst = match rng.range_inclusive(0, 3) {
        0 => generate_fork_join(1, rng.range_inclusive(1, 8), ccr, beta_pct, &model, &plat, seed),
        1 => generate_fork_join(
            rng.range_inclusive(2, 5),
            rng.range_inclusive(1, 5),
            ccr,
            beta_pct,
            &model,
            &plat,
            seed,
        ),
        2 => generate_pipeline(
            rng.range_inclusive(1, 6),
            rng.range_inclusive(2, 5),
            ccr,
            beta_pct,
            &model,
            &plat,
            seed,
        ),
        _ => {
            let mut edges = Vec::new();
            let mut next = 2usize;
            let mut budget = rng.range_inclusive(2, 12);
            build_sp(rng, 0, 1, &mut budget, &mut edges, &mut next);
            let classes = plat.num_classes();
            let comp: Vec<f64> = (0..next * classes).map(|_| rng.uniform(0.5, 20.0)).collect();
            Instance {
                graph: TaskGraph::from_edges(next, &edges),
                comp: CostMatrix::new(classes, comp),
            }
        }
    };
    (inst, plat, seed)
}

#[test]
fn prop_sp_tree_dp_bit_identical_to_general() {
    // The series-parallel tree-DP kernel must reproduce the general
    // kernel bit for bit — values, backpointers (argmins), tie-breaking,
    // and therefore every derived placement — in both orientations and
    // under both lane dispatches, over recognizer-accepted random SP
    // graphs and the explicit chain/fork-join/pipeline constructions,
    // including P == 1 platforms.
    check_property(
        "sp tree-DP == general kernel (both orientations, both lanes)",
        default_cases(),
        0xCEF7_0030,
        |rng| arb_sp_instance(rng),
        |(inst, plat, seed)| {
            let verdict = shape::recognize(&inst.graph);
            let sp = verdict.sp.as_ref().ok_or_else(|| {
                format!(
                    "recognizer rejected a constructed SP graph (class {:?}, seed {seed})",
                    verdict.class
                )
            })?;
            let iref = inst.bind(plat);
            let mut spw = Workspace::new();
            let mut gw = Workspace::new();
            for &d in &[KernelDispatch::Scalar, KernelDispatch::Simd] {
                ceft_table_sp_into_dispatched(&mut spw, iref, sp, d);
                ceft_table_into_dispatched(&mut gw, iref, d);
                if spw.table != gw.table || spw.backptr != gw.backptr {
                    return Err(format!("forward sp tree-DP diverged ({d:?}, seed {seed})"));
                }
                ceft_table_sp_rev_into_dispatched(&mut spw, iref, sp, d);
                ceft_table_rev_into_dispatched(&mut gw, iref, d);
                if spw.table != gw.table || spw.backptr != gw.backptr {
                    return Err(format!("reverse sp tree-DP diverged ({d:?}, seed {seed})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shape_recognizer_sound() {
    // Soundness of the SP verdict: the decomposition must re-expand to
    // exactly the graph's edge set (every edge index once, none invented)
    // and its derived order must be a source-to-sink permutation of all
    // tasks. The N-graph — the canonical non-SP witness — must always
    // come back General with no decomposition.
    let ngraph = TaskGraph::from_edges(
        4,
        &[
            (0, 1, 1.0),
            (0, 2, 1.0),
            (1, 2, 1.0),
            (1, 3, 1.0),
            (2, 3, 1.0),
        ],
    );
    let nv = shape::recognize(&ngraph);
    assert_eq!(nv.class, ShapeClass::General, "N-graph must classify General");
    assert!(nv.sp.is_none(), "General verdict must carry no decomposition");
    check_property(
        "SP decomposition re-expands to the exact edge set",
        default_cases(),
        0xCEF7_0031,
        |rng| arb_sp_instance(rng),
        |(inst, _plat, seed)| {
            let verdict = shape::recognize(&inst.graph);
            let sp = verdict
                .sp
                .as_ref()
                .ok_or_else(|| format!("recognizer rejected a constructed SP graph (seed {seed})"))?;
            let m = inst.graph.num_edges();
            let mut leaves = sp.leaf_edges();
            leaves.sort_unstable();
            if leaves != (0..m).collect::<Vec<_>>() {
                return Err(format!(
                    "decomposition re-expands to {} leaves over {m} edges (seed {seed})",
                    leaves.len()
                ));
            }
            let n = inst.graph.num_tasks();
            if sp.order.len() != n {
                return Err(format!("order covers {} of {n} tasks (seed {seed})", sp.order.len()));
            }
            let mut seen = vec![false; n];
            for &t in &sp.order {
                if t >= n || seen[t] {
                    return Err(format!("order is not a permutation at task {t} (seed {seed})"));
                }
                seen[t] = true;
            }
            if sp.order[0] != sp.source || sp.order[n - 1] != sp.sink {
                return Err(format!("order endpoints are not source/sink (seed {seed})"));
            }
            Ok(())
        },
    );
}
