//! Property-based tests over randomly generated instances, via the
//! in-repo property harness (`ceft::util::prop`). Each property runs
//! `CEFT_PROP_CASES` (default 64) randomized cases with reproducible seeds.

use ceft::cp::ceft::{ceft_table, find_critical_path};
use ceft::cp::cpmin::cp_min_cost;
use ceft::cp::minexec::min_exec_critical_path;
use ceft::graph::generator::{generate, Instance, RggParams};
use ceft::platform::{CostModel, Platform};
use ceft::sched::{
    ceft_cpop::CeftCpop, ceft_heft::CeftHeftUp, cpop::Cpop, heft::Heft, Scheduler,
};
use ceft::util::prop::{check_property, default_cases};
use ceft::util::rng::Xoshiro256;

/// Random instance generator spanning both cost models, platform comm
/// heterogeneity, all sizes the unit tests don't reach.
fn arb_instance(rng: &mut Xoshiro256) -> (Instance, Platform, u64) {
    let n = rng.range_inclusive(2, 120);
    let p = *rng.choose(&[1usize, 2, 3, 4, 8, 16]);
    let two_weight = rng.chance(0.4) && p >= 2;
    let seed = rng.next_u64();
    let plat = if two_weight {
        Platform::two_weight(p, rng.uniform(0.1, 0.9), rng, 1.0, 0.0)
    } else if rng.chance(0.5) {
        Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 2.0))
    } else {
        Platform::random_links(p, rng, 0.2, 5.0, 0.0, 2.0)
    };
    let model = if two_weight {
        CostModel::two_weight_medium(0.5)
    } else {
        CostModel::Classic {
            beta: rng.uniform(0.0, 1.0),
        }
    };
    let params = RggParams {
        n,
        out_degree: rng.range_inclusive(1, 6),
        ccr: *rng.choose(&[0.001, 0.1, 1.0, 10.0]),
        alpha: rng.uniform(0.1, 1.0),
        beta_pct: rng.uniform(0.0, 100.0),
        gamma: rng.uniform(0.0, 1.0),
    };
    let inst = generate(&params, &model, &plat, seed);
    (inst, plat, seed)
}

#[test]
fn prop_every_schedule_is_valid() {
    check_property(
        "every schedule valid",
        default_cases(),
        0xCEF7_0001,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let algos: [&dyn Scheduler; 4] = [&Cpop, &Heft, &CeftCpop, &CeftHeftUp];
            for a in algos {
                let s = a.schedule(&inst.graph, plat, &inst.comp);
                s.validate(&inst.graph, plat, &inst.comp)
                    .map_err(|e| format!("{} (seed {seed}): {e}", a.name()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cpl_bounds() {
    check_property(
        "cp_min <= minexec <= ceft",
        default_cases(),
        0xCEF7_0002,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let p = plat.num_classes();
            let cpmin = cp_min_cost(&inst.graph, &inst.comp, p);
            let me = min_exec_critical_path(&inst.graph, plat, &inst.comp, false);
            let cp = find_critical_path(&inst.graph, plat, &inst.comp);
            if cpmin > me.length + 1e-9 {
                return Err(format!("cp_min {cpmin} > minexec {}", me.length));
            }
            if me.length > cp.length + 1e-9 {
                return Err(format!("minexec {} > ceft {}", me.length, cp.length));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_makespan_dominates_cpmin_and_slr_ge_one() {
    check_property(
        "makespan >= cp_min, slr >= 1",
        default_cases(),
        0xCEF7_0003,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let p = plat.num_classes();
            let cpmin = cp_min_cost(&inst.graph, &inst.comp, p);
            for a in [&Cpop as &dyn Scheduler, &Heft, &CeftCpop] {
                let m = a.schedule(&inst.graph, plat, &inst.comp).makespan();
                if m + 1e-6 < cpmin {
                    return Err(format!("{}: makespan {m} < cp_min {cpmin}", a.name()));
                }
                let slr = ceft::metrics::slr(&inst.graph, &inst.comp, p, m);
                if slr < 1.0 - 1e-9 {
                    return Err(format!("{}: slr {slr} < 1", a.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ceft_path_structure() {
    check_property(
        "ceft path connected source->sink with consistent table",
        default_cases(),
        0xCEF7_0004,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let cp = find_critical_path(&inst.graph, plat, &inst.comp);
            if cp.path.is_empty() {
                return Err("empty path".into());
            }
            if inst.graph.in_degree(cp.path[0].task) != 0 {
                return Err("path does not start at a source".into());
            }
            if inst.graph.out_degree(cp.path.last().unwrap().task) != 0 {
                return Err("path does not end at a sink".into());
            }
            for w in cp.path.windows(2) {
                if !inst
                    .graph
                    .succs(w[0].task)
                    .iter()
                    .any(|&(d, _)| d == w[1].task)
                {
                    return Err(format!("missing edge {} -> {}", w[0].task, w[1].task));
                }
            }
            // length matches the table cell of the final step
            let table = ceft_table(&inst.graph, plat, &inst.comp);
            let last = cp.path.last().unwrap();
            let cell = table.get(last.task, last.class);
            if (cell - cp.length).abs() > 1e-9 {
                return Err(format!("table cell {cell} != length {}", cp.length));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ceft_monotone_under_cost_increase() {
    // raising a single task's execution cost can never shorten the CPL
    check_property(
        "ceft monotone in comp costs",
        default_cases(),
        0xCEF7_0005,
        |rng| {
            let (inst, plat, seed) = arb_instance(rng);
            let t = rng.below(inst.graph.num_tasks());
            let bump = rng.uniform(1.0, 100.0);
            (inst, plat, seed, t, bump)
        },
        |(inst, plat, _, t, bump)| {
            let p = plat.num_classes();
            let before = find_critical_path(&inst.graph, plat, &inst.comp).length;
            let mut comp2 = inst.comp.clone();
            for j in 0..p {
                comp2[t * p + j] += bump;
            }
            let after = find_critical_path(&inst.graph, plat, &comp2).length;
            if after + 1e-9 < before {
                return Err(format!("CPL dropped {before} -> {after} after raising task {t}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ceft_scale_invariance() {
    // multiplying all costs (comp and comm payloads) by s scales CPL by s
    check_property(
        "ceft scale invariance",
        default_cases() / 2,
        0xCEF7_0006,
        |rng| {
            let (inst, plat, seed) = arb_instance(rng);
            (inst, plat, seed, rng.uniform(0.5, 8.0))
        },
        |(inst, plat, _, s)| {
            let before = find_critical_path(&inst.graph, plat, &inst.comp).length;
            let comp2: Vec<f64> = inst.comp.iter().map(|c| c * s).collect();
            let edges2: Vec<(usize, usize, f64)> = inst
                .graph
                .edges()
                .iter()
                .map(|e| (e.src, e.dst, e.data * s))
                .collect();
            // scale startup too: rebuild a platform clone is not exposed, so
            // only run this property on zero-startup platforms
            if (0..plat.num_classes()).any(|j| plat.startup(j) != 0.0) {
                return Ok(()); // skip non-zero-startup draws
            }
            let g2 = ceft::graph::TaskGraph::from_edges(inst.graph.num_tasks(), &edges2);
            let after = find_critical_path(&g2, plat, &comp2).length;
            let rel = (after - s * before).abs() / (s * before).max(1e-12);
            if rel > 1e-9 {
                return Err(format!("scaled CPL {after} != {s} * {before}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pinned_tasks_respected() {
    check_property(
        "ceft-cpop pins its critical path",
        default_cases() / 2,
        0xCEF7_0007,
        |rng| arb_instance(rng),
        |(inst, plat, _)| {
            let cp = find_critical_path(&inst.graph, plat, &inst.comp);
            let s = CeftCpop.schedule(&inst.graph, plat, &inst.comp);
            for step in &cp.path {
                if s.assignments[step.task].proc != step.class {
                    return Err(format!(
                        "task {} scheduled on {} instead of pinned {}",
                        step.task, s.assignments[step.task].proc, step.class
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transposed_ceft_symmetric_on_chains() {
    // On a *chain* (single path) with symmetric zero-startup comm, the CPL
    // is direction-invariant: reversing the optimal assignment of the
    // reversed chain gives the same cost. (On general DAGs this is NOT a
    // theorem — the DP anchors its final `min` at the sink's class, and
    // transposition moves that anchor to the source.)
    check_property(
        "chain CPL(G) == CPL(G^T) under symmetric comm",
        default_cases() / 2,
        0xCEF7_0008,
        |rng| {
            let n = rng.range_inclusive(2, 60);
            let p = *rng.choose(&[2usize, 4, 8]);
            let plat = Platform::uniform(p, rng.uniform(0.2, 5.0), 0.0);
            let edges: Vec<(usize, usize, f64)> = (0..n - 1)
                .map(|i| (i, i + 1, rng.uniform(0.0, 50.0)))
                .collect();
            let g = ceft::graph::TaskGraph::from_edges(n, &edges);
            let comp: Vec<f64> = (0..n * p).map(|_| rng.uniform(1.0, 40.0)).collect();
            (g, plat, comp)
        },
        |(g, plat, comp)| {
            let fwd = find_critical_path(g, plat, comp).length;
            let bwd = find_critical_path(&g.transpose(), plat, comp).length;
            if (fwd - bwd).abs() > 1e-6 * fwd.max(1.0) {
                return Err(format!("fwd {fwd} != bwd {bwd}"));
            }
            Ok(())
        },
    );
}
