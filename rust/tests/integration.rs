//! Integration tests across modules: generators → CP algorithms →
//! schedulers → metrics, plus cross-checks between independent
//! implementations of the same quantity.

use ceft::cp::ceft::{ceft_table, chain_optimal_length, find_critical_path};
use ceft::cp::cpmin::cp_min_cost;
use ceft::cp::minexec::min_exec_critical_path;
use ceft::cp::ranks::{cpop_critical_path, cpop_realized_cp_length, rank_upward};
use ceft::exp::cells::{grid, realworld_grid, RealWorld, Scale, Workload};
use ceft::exp::run::{run_cell, run_realworld_cell};
use ceft::graph::generator::{generate, Instance, RggParams};
use ceft::graph::realworld;
use ceft::graph::TaskGraph;
use ceft::metrics;
use ceft::model::{CostMatrix, InstanceRef};
use ceft::platform::{CostModel, Platform};
use ceft::sched::{
    ceft_cpop::CeftCpop,
    ceft_heft::{CeftHeftDown, CeftHeftUp},
    cpop::Cpop,
    heft::{Heft, HeftDown},
    Scheduler,
};
use ceft::util::rng::Xoshiro256;

fn rgg(seed: u64, n: usize, p: usize, ccr: f64) -> (Instance, Platform) {
    let plat = Platform::uniform(p, 1.0, 0.0);
    let inst = generate(
        &RggParams {
            n,
            out_degree: 4,
            ccr,
            alpha: 0.5,
            beta_pct: 75.0,
            gamma: 0.25,
        },
        &CostModel::Classic { beta: 0.75 },
        &plat,
        seed,
    );
    (inst, plat)
}

/// Every scheduler produces a valid schedule on every workload family and
/// a spread of sizes/platforms — the whole-stack smoke matrix.
#[test]
fn all_schedulers_valid_on_all_workloads() {
    let schedulers: [&dyn Scheduler; 6] = [
        &Cpop,
        &Heft,
        &CeftCpop,
        &HeftDown,
        &CeftHeftUp,
        &CeftHeftDown,
    ];
    for wl in Workload::ALL {
        for (seed, &(n, p)) in [(64usize, 2usize), (128, 8), (200, 16)].iter().enumerate() {
            let mut prng = Xoshiro256::new(seed as u64 + wl.id() * 100);
            let plat = if wl.needs_two_weight_platform() {
                Platform::two_weight(p, 0.5, &mut prng, 1.0, 0.0)
            } else {
                Platform::uniform(p, 1.0, 0.0)
            };
            let inst = generate(
                &RggParams {
                    n,
                    out_degree: 3,
                    ccr: 1.0,
                    alpha: 0.5,
                    beta_pct: 50.0,
                    gamma: 0.25,
                },
                &wl.cost_model(50.0),
                &plat,
                seed as u64,
            );
            let iref = inst.bind(&plat);
            for s in schedulers {
                let sched = s.schedule(iref);
                sched
                    .validate(iref)
                    .unwrap_or_else(|e| panic!("{} on {} n={n} p={p}: {e}", s.name(), wl.name()));
            }
        }
    }
}

/// The lower-bound lattice: CP_MIN <= minexec CP <= CEFT CPL <= any makespan
/// whose schedule respects dependencies... (the last only when comm costs
/// don't let a schedule "beat" the CEFT path — CP_MIN is the only hard
/// bound, but the first two orderings are structural).
#[test]
fn bound_ordering_holds() {
    for seed in 0..20 {
        let (inst, plat) = rgg(seed, 150, 8, 1.0);
        let iref = inst.bind(&plat);
        let cpmin = cp_min_cost(iref);
        let me = min_exec_critical_path(iref, false);
        let ceft = find_critical_path(iref);
        assert!(cpmin <= me.length + 1e-9, "seed {seed}");
        assert!(me.length <= ceft.length + 1e-9, "seed {seed}");
        for s in [
            Cpop.schedule(iref),
            Heft.schedule(iref),
            CeftCpop.schedule(iref),
        ] {
            assert!(s.makespan() + 1e-9 >= cpmin, "makespan below CP_MIN, seed {seed}");
        }
    }
}

/// With a single processor class, every algorithm collapses to the same
/// serial makespan and CEFT equals the classical longest path.
#[test]
fn single_class_degeneracy() {
    let (inst, plat) = rgg(3, 100, 1, 1.0);
    let iref = inst.bind(&plat);
    let serial: f64 = inst.comp.as_slice().iter().sum();
    for s in [
        Cpop.schedule(iref),
        Heft.schedule(iref),
        CeftCpop.schedule(iref),
    ] {
        assert!((s.makespan() - serial).abs() < 1e-6);
    }
    let ceft = find_critical_path(iref);
    let classic = inst.graph.longest_path(inst.comp.as_slice(), |_, _, _| 0.0);
    assert!((ceft.length - classic).abs() < 1e-9);
}

/// CEFT length via the DP equals the chain re-evaluation of its own path
/// when the path's assignment is re-optimised chain-locally — and the
/// reported assignment achieves a length >= the chain optimum (Definition 7
/// consistency).
#[test]
fn ceft_path_self_consistency() {
    for seed in 0..10 {
        let (inst, plat) = rgg(seed + 50, 120, 4, 2.0);
        let iref = inst.bind(&plat);
        let cp = find_critical_path(iref);
        let chain = chain_optimal_length(iref, &cp.tasks());
        assert!(
            chain <= cp.length + 1e-9,
            "chain optimum {chain} exceeds DP length {}",
            cp.length
        );
        // realized length of the reported assignment along the chain
        let mut realized = 0.0;
        for (i, step) in cp.path.iter().enumerate() {
            if i > 0 {
                let prev = &cp.path[i - 1];
                let data = inst
                    .graph
                    .succs(prev.task)
                    .iter()
                    .find(|&&(d, _)| d == step.task)
                    .unwrap()
                    .1;
                realized += plat.comm_cost(prev.class, step.class, data);
            }
            realized += inst.comp.get(step.task, step.class);
        }
        assert!(
            realized <= cp.length + 1e-9,
            "assignment realization {realized} exceeds CPL {}",
            cp.length
        );
    }
}

/// CPOP's realized CP cost can never beat the per-task minimum sum of its
/// own path, and CEFT's CPL is within [cp_min, cpop mean estimate * big].
#[test]
fn cpop_realized_bounds() {
    for seed in 0..10 {
        let (inst, plat) = rgg(seed + 80, 100, 8, 0.5);
        let iref = inst.bind(&plat);
        let (cp, estimate) = cpop_critical_path(iref);
        let realized = cpop_realized_cp_length(&cp, &inst.comp);
        let per_task_min: f64 = cp.iter().map(|&t| inst.comp.min(t)).sum();
        assert!(realized + 1e-9 >= per_task_min, "seed {seed}");
        assert!(estimate > 0.0 && realized > 0.0);
    }
}

/// HEFT's priority order (descending rank_u) is topologically consistent:
/// parents strictly precede children.
#[test]
fn heft_rank_topological_consistency() {
    let (inst, plat) = rgg(7, 200, 8, 1.0);
    let rank = rank_upward(inst.bind(&plat));
    for e in inst.graph.edges() {
        assert!(
            rank[e.src] > rank[e.dst],
            "rank_u({}) = {} !> rank_u({}) = {}",
            e.src,
            rank[e.src],
            e.dst,
            rank[e.dst]
        );
    }
}

/// Real-world generators feed the whole pipeline.
#[test]
fn realworld_families_full_pipeline() {
    for fam in RealWorld::ALL {
        for cell in realworld_grid(fam, Scale::Smoke) {
            let row = run_realworld_cell(&cell);
            assert!(row.cp_min > 0.0);
            assert!(row.cpl_ceft + 1e-9 >= row.cp_min, "{}", fam.name());
            for a in &row.algos {
                assert!(a.slr >= 1.0 - 1e-9, "{} slr {}", fam.name(), a.slr);
            }
        }
    }
}

/// Experiment rows are bit-reproducible across runs and threads.
#[test]
fn experiment_cells_reproducible() {
    for wl in [Workload::RggClassic, Workload::RggHigh] {
        let cells = grid(wl, Scale::Smoke);
        let a = run_cell(&cells[0]);
        let b = run_cell(&cells[0]);
        assert_eq!(a.cpl_ceft.to_bits(), b.cpl_ceft.to_bits());
        for (x, y) in a.algos.iter().zip(&b.algos) {
            assert_eq!(x.makespan.to_bits(), y.makespan.to_bits());
            assert_eq!(x.slack.to_bits(), y.slack.to_bits());
        }
    }
}

/// The FFT graph: the paper notes every root→exit path is a critical path
/// when costs are uniform — check CEFT agrees (all sinks have equal CEFT
/// min within tolerance under uniform costs).
#[test]
fn fft_all_paths_critical_under_uniform_costs() {
    let skel = realworld::fft(8);
    let edges: Vec<(usize, usize, f64)> =
        skel.edges.iter().map(|&(s, d)| (s, d, 1.0)).collect();
    let g = TaskGraph::from_edges(skel.n, &edges);
    let plat = Platform::uniform(2, 1.0, 0.0);
    let comp = CostMatrix::new(2, vec![1.0; skel.n * 2]);
    let table = ceft_table(InstanceRef::new(&g, &plat, &comp));
    let sink_mins: Vec<f64> = g
        .sinks()
        .iter()
        .map(|&s| table.min_over_classes(s))
        .collect();
    let first = sink_mins[0];
    for m in &sink_mins {
        assert!((m - first).abs() < 1e-9, "sink CEFTs differ: {sink_mins:?}");
    }
}

/// Speedup can exceed 1 only through genuine parallelism, and the serial
/// schedule achieves exactly speedup 1 on its own best processor.
#[test]
fn speedup_semantics() {
    let (inst, plat) = rgg(11, 150, 8, 0.1);
    let iref = inst.bind(&plat);
    let s = Heft.schedule(iref);
    let sp = metrics::speedup(&inst.comp, s.makespan());
    assert!(sp > 1.0, "HEFT at low CCR should parallelise, speedup={sp}");
    let serial = metrics::serial_time(&inst.comp);
    assert!((metrics::speedup(&inst.comp, serial) - 1.0).abs() < 1e-12);
}
