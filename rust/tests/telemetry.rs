//! Telemetry integration tests over the public API: recorder conservation
//! under concurrent recording, engine stage attribution end-to-end through
//! the JSON protocol, and the `trace` / `metrics` surfacing ops. The
//! histogram bucket/percentile/merge unit tests live in `obs::hist`; the
//! deterministic batched-attribution test lives in `service::engine` —
//! this file exercises the same taxonomy from outside the crate.

use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::build_instance;
use ceft::graph::io;
use ceft::obs::{Recorder, Stage};
use ceft::service::{Engine, EngineConfig};
use ceft::util::json::Json;
use std::sync::Arc;

fn instance_line(op: &str, algo: Option<&str>, index: u64) -> String {
    let mut cell = grid(Workload::RggClassic, Scale::Smoke)[0];
    cell.index += index;
    let (platform, inst) = build_instance(&cell);
    let algo_field = algo
        .map(|a| format!(r#""algorithm":"{a}","#))
        .unwrap_or_default();
    format!(
        r#"{{"op":"{op}",{algo_field}"instance":{},"platform":{}}}"#,
        io::instance_to_json(&inst).to_string(),
        io::platform_to_json(&platform).to_string()
    )
}

fn telemetry_engine() -> Engine {
    Engine::new(EngineConfig {
        telemetry: Some(true),
        ..EngineConfig::default()
    })
}

#[test]
fn concurrent_recording_conserves_totals() {
    // N threads × M traces, each adding a known arithmetic series to
    // `kernel` and a constant to `parse`: after the dust settles the
    // merged histograms must hold exactly every sample — counts and sums
    // conserved, nothing dropped or double-counted by the per-thread
    // sinks or the seqlocked snapshot.
    const THREADS: u64 = 8;
    const TRACES: u64 = 200;
    let rec = Arc::new(Recorder::new(true));
    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let rec = rec.clone();
            std::thread::spawn(move || {
                for i in 1..=TRACES {
                    let mut t = rec.begin(2);
                    t.add(Stage::Kernel, i);
                    t.add(Stage::Parse, 7);
                    t.finish();
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let snap = rec.snapshot();
    let kernel = &snap.stages[Stage::Kernel.idx()];
    assert_eq!(kernel.count, THREADS * TRACES);
    assert_eq!(kernel.sum, THREADS * TRACES * (TRACES + 1) / 2);
    assert_eq!(kernel.max, TRACES);
    let parse = &snap.stages[Stage::Parse.idx()];
    assert_eq!(parse.count, THREADS * TRACES);
    assert_eq!(parse.sum, 7 * THREADS * TRACES);
    // untouched stages stay empty
    assert_eq!(snap.stages[Stage::QueueWait.idx()].count, 0);
    // retention bounds hold and the slow log is sorted slowest-first
    assert!(snap.recent.len() <= ceft::obs::recorder::SNAPSHOT_TRACES);
    assert!(!snap.slowest.is_empty());
    for pair in snap.slowest.windows(2) {
        assert!(pair[0].total_ns >= pair[1].total_ns, "slow log unsorted");
    }
}

#[test]
fn disabled_recorder_records_nothing() {
    let rec = Recorder::new(false);
    for _ in 0..50 {
        let mut t = rec.begin(3);
        t.add(Stage::Kernel, 1000);
        {
            let _span = t.span(Stage::Respond);
        }
        t.finish();
    }
    let snap = rec.snapshot();
    for s in Stage::ALL {
        assert_eq!(snap.stages[s.idx()].count, 0, "{} leaked", s.name());
    }
    assert!(snap.slowest.is_empty() && snap.recent.is_empty());
}

#[test]
fn serial_protocol_requests_attribute_stages() {
    // One schedule miss, its cached repeat, and a cp miss through the
    // wire protocol: compute stages populate, batching stages must not —
    // sequential requests never enter a width ≥ 2 gather.
    let engine = telemetry_engine();
    let sched = instance_line("schedule", Some("CEFT-CPOP"), 0);
    for line in [&sched, &sched, &instance_line("cp", None, 0)] {
        let (resp, _) = engine.handle_line(line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    }
    let stats = engine.stats_json();
    assert_eq!(stats.get("telemetry").and_then(Json::as_str), Some("on"));
    let count = |name: &str| {
        stats
            .get("stages")
            .and_then(|s| s.get(name))
            .and_then(|h| h.get("count"))
            .and_then(Json::as_f64)
            .unwrap_or(f64::NAN)
    };
    assert_eq!(count("parse"), 3.0);
    assert_eq!(count("intern"), 3.0, "every inline target interns");
    assert_eq!(count("ctx_build"), 1.0, "panels built exactly once");
    assert_eq!(count("kernel"), 2.0, "schedule miss + cp miss");
    assert_eq!(count("respond"), 3.0);
    assert!(count("cache_probe") >= 3.0);
    assert_eq!(count("queue_wait"), 0.0, "no gather on a serial stream");
    assert_eq!(count("batch_drain"), 0.0, "no gather on a serial stream");
    // batching counters agree with the stage taxonomy
    let batched = stats
        .get("cp_cache")
        .and_then(|c| c.get("batched_requests"))
        .and_then(Json::as_f64);
    assert_eq!(batched, Some(0.0));
}

#[test]
fn trace_op_returns_all_stages_and_respects_limit() {
    let engine = telemetry_engine();
    for i in 0..4 {
        let (resp, _) = engine.handle_line(&instance_line("schedule", Some("HEFT"), i));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }
    let (resp, _) = engine.handle_line(r#"{"op":"trace","limit":2}"#);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let stages = resp.get("stages").expect("stages section");
    for s in Stage::ALL {
        let h = stages.get(s.name()).expect("every stage always present");
        assert!(h.get("p99_us").is_some(), "{} lacks percentiles", s.name());
    }
    for list in ["slowest", "recent"] {
        let arr = resp.get(list).and_then(Json::as_arr).expect(list);
        assert!(!arr.is_empty() && arr.len() <= 2, "{list} ignores limit");
        for r in arr {
            assert_eq!(r.get("op").and_then(Json::as_str), Some("schedule"));
            assert!(r.get("total_us").and_then(Json::as_f64).unwrap() > 0.0);
        }
    }
}

#[test]
fn metrics_op_serves_prometheus_exposition() {
    let engine = telemetry_engine();
    let (resp, _) = engine.handle_line(&instance_line("schedule", Some("CPOP"), 0));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let (m, _) = engine.handle_line(r#"{"op":"metrics"}"#);
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)));
    let text = m.get("text").and_then(Json::as_str).expect("text body");
    for family in [
        "ceft_requests_total",
        "ceft_schedule_requests_total",
        "ceft_batched_requests_total",
        "ceft_stage_latency_seconds",
        "ceft_kernel_calls_total",
    ] {
        assert!(text.contains(family), "missing metric family {family}");
    }
    // the summary carries per-stage labelled quantiles
    assert!(text.contains(r#"stage="kernel",quantile="0.5""#));
    assert!(text.contains("ceft_stage_latency_seconds_count"));
}

#[test]
fn engine_toggle_overrides_process_switch() {
    // `telemetry: Some(false)` must silence an engine even when the
    // process switch is on: the stats report says "off" and no stage
    // records a sample.
    let engine = Engine::new(EngineConfig {
        telemetry: Some(false),
        ..EngineConfig::default()
    });
    let (resp, _) = engine.handle_line(&instance_line("cp", None, 0));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let stats = engine.stats_json();
    assert_eq!(stats.get("telemetry").and_then(Json::as_str), Some("off"));
    let respond_count = stats
        .get("stages")
        .and_then(|s| s.get("respond"))
        .and_then(|h| h.get("count"))
        .and_then(Json::as_f64);
    assert_eq!(respond_count, Some(0.0));
}
