//! Workspace-reuse equivalence tests: the tentpole guarantee of the
//! allocation-free refactor is that results are **bit-identical** whether
//! the algorithm core runs over a fresh workspace, a reused workspace, or
//! the classic allocating signatures. CEFT's deterministic tie-breaking
//! (lowest class, earliest parent, lowest sink id) is load-bearing for the
//! service memo caches and the batch/online equivalence guarantee, so these
//! properties compare full structures, not just lengths.

use ceft::cp::ceft::{find_critical_path, find_critical_path_with};
use ceft::cp::cpmin::{cp_min_cost, cp_min_cost_with};
use ceft::cp::minexec::{min_exec_critical_path, min_exec_critical_path_with};
use ceft::cp::workspace::{Workspace, WorkspacePool};
use ceft::graph::generator::{generate, Instance, RggParams};
use ceft::platform::{CostModel, Platform};
use ceft::sched::{Algorithm, Schedule};
use ceft::util::prop::{check_property, default_cases};
use ceft::util::rng::Xoshiro256;

/// Random instance generator spanning both cost models and platform comm
/// heterogeneity (mirrors `properties.rs`).
fn arb_instance(rng: &mut Xoshiro256) -> (Instance, Platform, u64) {
    let n = rng.range_inclusive(2, 100);
    let p = *rng.choose(&[1usize, 2, 3, 4, 8]);
    let two_weight = rng.chance(0.4) && p >= 2;
    let seed = rng.next_u64();
    let plat = if two_weight {
        Platform::two_weight(p, rng.uniform(0.1, 0.9), rng, 1.0, 0.0)
    } else if rng.chance(0.5) {
        Platform::uniform(p, rng.uniform(0.2, 5.0), rng.uniform(0.0, 2.0))
    } else {
        Platform::random_links(p, rng, 0.2, 5.0, 0.0, 2.0)
    };
    let model = if two_weight {
        CostModel::two_weight_medium(0.5)
    } else {
        CostModel::Classic {
            beta: rng.uniform(0.0, 1.0),
        }
    };
    let params = RggParams {
        n,
        out_degree: rng.range_inclusive(1, 5),
        ccr: *rng.choose(&[0.1, 1.0, 10.0]),
        alpha: rng.uniform(0.1, 1.0),
        beta_pct: rng.uniform(0.0, 100.0),
        gamma: rng.uniform(0.0, 1.0),
    };
    let inst = generate(&params, &model, &plat, seed);
    (inst, plat, seed)
}

fn schedules_equal(a: &Schedule, b: &Schedule) -> bool {
    a.p == b.p && a.assignments == b.assignments
}

#[test]
fn prop_reused_workspace_is_bit_identical_to_fresh() {
    check_property(
        "reused workspace == fresh allocations (CP + all schedules)",
        default_cases(),
        0xCEF7_0010,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            let mut ws = Workspace::new();
            // twice through ONE reused workspace …
            let cp_a = find_critical_path_with(&mut ws, iref);
            let cp_b = find_critical_path_with(&mut ws, iref);
            // … once through fresh allocations (the classic signature)
            let cp_fresh = find_critical_path(iref);
            if cp_a != cp_fresh || cp_b != cp_fresh {
                return Err(format!("critical path diverged (seed {seed})"));
            }
            for algo in Algorithm::ALL {
                let a = algo.run_with(&mut ws, iref);
                let b = algo.run_with(&mut ws, iref);
                let fresh = algo.schedule(iref);
                if !schedules_equal(&a, &fresh) || !schedules_equal(&b, &fresh) {
                    return Err(format!("{} diverged (seed {seed})", algo.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cp_baselines_match_through_reused_workspace() {
    check_property(
        "cpmin/minexec workspace variants == allocating variants",
        default_cases() / 2,
        0xCEF7_0011,
        |rng| arb_instance(rng),
        |(inst, plat, seed)| {
            let iref = inst.bind(plat);
            let mut ws = Workspace::new();
            for _ in 0..2 {
                let a = cp_min_cost_with(&mut ws, iref);
                let b = cp_min_cost(iref);
                if a.to_bits() != b.to_bits() {
                    return Err(format!("cp_min {a} != {b} (seed {seed})"));
                }
                for mean_comm in [false, true] {
                    let me_a = min_exec_critical_path_with(&mut ws, iref, mean_comm);
                    let me_b = min_exec_critical_path(iref, mean_comm);
                    if me_a != me_b {
                        return Err(format!("minexec diverged (seed {seed})"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Poisoning: a workspace dirtied by a *larger* instance (longer buffers,
/// more processors, deeper heap, larger comm panels) must not leak any
/// state into a smaller instance scheduled right after.
#[test]
fn dirty_workspace_from_larger_instance_cannot_poison_smaller_one() {
    let plat_big = Platform::uniform(8, 1.0, 0.1);
    let big = generate(
        &RggParams {
            n: 400,
            out_degree: 5,
            ccr: 1.0,
            alpha: 0.5,
            beta_pct: 75.0,
            gamma: 0.3,
        },
        &CostModel::Classic { beta: 0.75 },
        &plat_big,
        1,
    );
    let plat_small = Platform::uniform(2, 2.0, 0.0);
    let small = generate(
        &RggParams {
            n: 12,
            out_degree: 2,
            ccr: 1.0,
            alpha: 0.5,
            beta_pct: 50.0,
            gamma: 0.2,
        },
        &CostModel::Classic { beta: 0.5 },
        &plat_small,
        2,
    );
    let big_ref = big.bind(&plat_big);
    let small_ref = small.bind(&plat_small);
    let mut ws = Workspace::new();
    // dirty every buffer with the big instance
    let _ = find_critical_path_with(&mut ws, big_ref);
    for algo in Algorithm::ALL {
        let _ = algo.run_with(&mut ws, big_ref);
    }
    let cap_after_big = ws.capacity_hint();
    // now the small instance, on the dirty workspace vs fresh
    let cp_dirty = find_critical_path_with(&mut ws, small_ref);
    let cp_fresh = find_critical_path(small_ref);
    assert_eq!(cp_dirty, cp_fresh, "dirty workspace leaked into CEFT");
    for algo in Algorithm::ALL {
        let dirty = algo.run_with(&mut ws, small_ref);
        let fresh = algo.schedule(small_ref);
        assert!(
            schedules_equal(&dirty, &fresh),
            "dirty workspace leaked into {}",
            algo.name()
        );
        dirty.validate(small_ref).unwrap();
    }
    // and the high-water capacity was reused, not reallocated away
    assert_eq!(
        ws.capacity_hint(),
        cap_after_big,
        "small instance must not shrink or regrow the arena"
    );
}

/// `Workspace::clear()` drops lengths but keeps capacity, and a cleared
/// workspace behaves exactly like a dirty one (entry points re-initialise
/// what they read either way).
#[test]
fn cleared_workspace_matches_dirty_and_keeps_capacity() {
    let plat = Platform::uniform(4, 1.0, 0.0);
    let inst = generate(
        &RggParams {
            n: 150,
            out_degree: 3,
            ccr: 1.0,
            alpha: 0.5,
            beta_pct: 50.0,
            gamma: 0.2,
        },
        &CostModel::Classic { beta: 0.5 },
        &plat,
        3,
    );
    let iref = inst.bind(&plat);
    let mut ws = Workspace::new();
    let first = Algorithm::CeftCpop.run_with(&mut ws, iref);
    let cap = ws.capacity_hint();
    ws.clear();
    assert_eq!(ws.capacity_hint(), cap, "clear must keep capacity");
    let second = Algorithm::CeftCpop.run_with(&mut ws, iref);
    assert!(schedules_equal(&first, &second));
}

/// The engine-facing pool hands out warmed workspaces without growing once
/// concurrency stabilises.
#[test]
fn workspace_pool_steady_state_does_not_grow() {
    let plat = Platform::uniform(3, 1.0, 0.0);
    let inst = generate(
        &RggParams {
            n: 60,
            out_degree: 3,
            ccr: 1.0,
            alpha: 0.5,
            beta_pct: 50.0,
            gamma: 0.2,
        },
        &CostModel::Classic { beta: 0.5 },
        &plat,
        4,
    );
    let pool = WorkspacePool::new();
    let mut results = Vec::new();
    for _ in 0..32 {
        results.push(pool.with(|ws| {
            Algorithm::Heft.run_with(ws, inst.bind(&plat)).makespan()
        }));
    }
    assert_eq!(pool.created(), 1, "sequential serving needs one workspace");
    assert!(results.windows(2).all(|w| w[0] == w[1]));
}
