//! The paper's §1 motivation at cluster scale: a CPU+GPU+FPGA-style machine
//! where averaging execution costs identifies a *misleading* critical path.
//!
//! Generates an RGG-high style instance (accelerator-like heterogeneity),
//! prints the three critical-path estimates side by side (CEFT, CPOP's
//! mean-value estimate, the min-exec baseline), then shows how the resulting
//! schedules diverge.
//!
//! Run with: `cargo run --release --example heterogeneous_cluster`

use ceft::cp::ceft::find_critical_path;
use ceft::cp::cpmin::cp_min_cost;
use ceft::cp::minexec::min_exec_critical_path;
use ceft::cp::ranks::{cpop_critical_path, cpop_realized_cp_length};
use ceft::graph::generator::{generate, RggParams};
use ceft::metrics;
use ceft::platform::{CostModel, Platform};
use ceft::sched::{ceft_cpop::CeftCpop, cpop::Cpop, heft::Heft, Scheduler};
use ceft::util::rng::Xoshiro256;

fn main() {
    // 8 processor classes with accelerator-like (two-weight) capacities.
    let mut rng = Xoshiro256::new(2024);
    let p = 8;
    let platform = Platform::two_weight(p, 0.5, &mut rng, 1.0, 0.0);

    let params = RggParams {
        n: 400,
        out_degree: 4,
        ccr: 0.5,
        alpha: 0.5,
        beta_pct: 50.0,
        gamma: 0.25,
    };
    let inst = generate(
        &params,
        &CostModel::two_weight_high(0.5),
        &platform,
        42,
    );
    let iref = inst.bind(&platform);
    let g = &inst.graph;
    println!(
        "instance: n={} e={} p={p} (two-weight 'high' heterogeneity)",
        g.num_tasks(),
        g.num_edges()
    );

    // --- critical-path estimates -----------------------------------------
    let ceft = find_critical_path(iref);
    let (cpop_path, cpop_estimate) = cpop_critical_path(iref);
    let cpop_realized = cpop_realized_cp_length(&cpop_path, &inst.comp);
    let minexec = min_exec_critical_path(iref, false);
    let lower = cp_min_cost(iref);

    println!("\n== critical-path estimates ==");
    println!("CP_MIN lower bound              : {lower:12.2}");
    println!("CEFT (optimal partial assignment): {:12.2}  ({} tasks)", ceft.length, ceft.path.len());
    println!("CPOP mean-value estimate        : {cpop_estimate:12.2}  ({} tasks)", cpop_path.len());
    println!("CPOP path realized on one proc  : {cpop_realized:12.2}");
    println!("min-exec baseline (zero comm)   : {:12.2}  ({} tasks)", minexec.length, minexec.tasks.len());
    println!(
        "\nmean-value estimate overshoots CEFT by {:.1}x — the paper's 'misleading path' effect",
        cpop_estimate / ceft.length
    );

    // how many distinct classes does the CEFT partial assignment use?
    let classes: std::collections::HashSet<usize> =
        ceft.path.iter().map(|s| s.class).collect();
    println!(
        "CEFT maps its {}-task path across {} distinct processor classes; CPOP forces 1",
        ceft.path.len(),
        classes.len()
    );

    // --- schedules --------------------------------------------------------
    println!("\n== schedules ==");
    let algos: [&dyn Scheduler; 3] = [&CeftCpop, &Cpop, &Heft];
    for a in algos {
        let s = a.schedule(iref);
        s.validate(iref).expect("valid");
        println!(
            "{:<10} makespan {:>12.2}  speedup {:>6.3}  slr {:>7.3}  slack {:>10.2}",
            a.name(),
            s.makespan(),
            metrics::speedup(&inst.comp, s.makespan()),
            metrics::slr(iref, s.makespan()),
            metrics::slack(iref, &s),
        );
    }
}
