//! The online scheduling service, embedded in-process: start an engine,
//! submit a handful of generated instances over the JSON line protocol,
//! request critical paths and schedules, then show the memoization at work
//! via the stats endpoint.
//!
//! The same frames work over `repro serve` (stdin/stdout or TCP); this
//! example drives the engine directly so it runs anywhere, instantly.
//!
//! Run with: `cargo run --release --example online_service`

use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::build_instance;
use ceft::graph::io;
use ceft::sched::Algorithm;
use ceft::service::{Engine, EngineConfig};
use ceft::util::json::Json;

fn main() {
    let engine = Engine::new(EngineConfig {
        cache_capacity: 256,
        threads: ceft::util::pool::default_threads(),
        ..EngineConfig::default()
    });

    // Five instances from the smoke grid, different seeds.
    let base = grid(Workload::RggClassic, Scale::Smoke)[0];
    let mut ids = Vec::new();
    println!("submitting 5 instances:");
    for i in 0..5u64 {
        let mut cell = base;
        cell.index = i;
        let (platform, inst) = build_instance(&cell);
        let line = format!(
            r#"{{"op":"submit","instance":{},"platform":{}}}"#,
            io::instance_to_json(&inst).to_string(),
            io::platform_to_json(&platform).to_string()
        );
        let (resp, _) = engine.handle_line(&line);
        let id = resp
            .get("id")
            .and_then(Json::as_str)
            .expect("submit response carries a handle")
            .to_string();
        println!(
            "  seed {i}: id={id} n={} edges={}",
            resp.get("n").and_then(Json::as_f64).unwrap(),
            resp.get("edges").and_then(Json::as_f64).unwrap()
        );
        ids.push(id);
    }

    // Critical path + two schedulers per instance, by handle.
    println!("\nper-instance results (first pass, every request computes):");
    for id in &ids {
        let (cp, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        let length = cp.get("length").and_then(Json::as_f64).unwrap();
        let mut makespans = Vec::new();
        for algo in [Algorithm::CeftCpop, Algorithm::Heft] {
            let (resp, _) = engine.handle_line(&format!(
                r#"{{"op":"schedule","algorithm":"{}","id":"{id}"}}"#,
                algo.name()
            ));
            assert_eq!(resp.get("cached"), Some(&Json::Bool(false)));
            makespans.push((
                algo.name(),
                resp.get("makespan").and_then(Json::as_f64).unwrap(),
            ));
        }
        println!(
            "  {id}: CPL {length:10.2}   {} {:10.2}   {} {:10.2}",
            makespans[0].0, makespans[0].1, makespans[1].0, makespans[1].1
        );
    }

    // Second pass: identical requests, now served from cache.
    let mut hits = 0;
    for id in &ids {
        let (resp, _) = engine.handle_line(&format!(
            r#"{{"op":"schedule","algorithm":"CEFT-CPOP","id":"{id}"}}"#
        ));
        if resp.get("cached") == Some(&Json::Bool(true)) {
            hits += 1;
        }
    }
    println!("\nsecond pass: {hits}/5 schedule requests served from cache");
    assert_eq!(hits, 5, "repeat requests must hit the memo cache");

    let (stats, _) = engine.handle_line(r#"{"op":"stats"}"#);
    println!("stats: {}", stats.to_string());
    println!("\nonline_service: OK");
}
