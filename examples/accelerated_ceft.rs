//! The three-layer stack end to end: load the AOT-compiled JAX/Pallas
//! relaxation artifact through PJRT and cross-validate the accelerated CEFT
//! backend against the pure-rust DP on a spread of instances.
//!
//! Requires `make artifacts` to have been run first.
//!
//! Run with: `cargo run --release --example accelerated_ceft`

use ceft::cp::ceft::find_critical_path;
use ceft::graph::generator::{generate, RggParams};
use ceft::platform::{CostModel, Platform};
use ceft::runtime::{AcceleratedCeft, PjrtRuntime};

fn main() {
    let rt = match PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT client unavailable: {e}");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform_name());
    let acc = AcceleratedCeft::new(rt);

    let mut checked = 0;
    for &p in &[2usize, 4, 8, 16] {
        if !acc.supports(p) {
            println!("p={p}: artifact missing (run `make artifacts`), skipping");
            continue;
        }
        for &n in &[64usize, 256, 512] {
            let plat = Platform::uniform(p, 1.0, 0.1);
            let inst = generate(
                &RggParams {
                    n,
                    out_degree: 4,
                    ccr: 1.0,
                    alpha: 0.5,
                    beta_pct: 75.0,
                    gamma: 0.25,
                },
                &CostModel::Classic { beta: 0.75 },
                &plat,
                n as u64 * 31 + p as u64,
            );
            let cpu = find_critical_path(inst.bind(&plat));
            let accel = acc
                .find_critical_path(inst.bind(&plat))
                .expect("accelerated CEFT");
            let rel = (cpu.length - accel.length).abs() / cpu.length;
            let paths_match = cpu.tasks() == accel.tasks();
            println!(
                "n={n:<4} p={p:<3} rust CPL {:>12.4}  pjrt CPL {:>12.4}  rel {:.2e}  paths {}",
                cpu.length,
                accel.length,
                rel,
                if paths_match { "identical" } else { "DIFFER" }
            );
            assert!(rel < 1e-4, "accelerated backend diverged");
            assert!(paths_match, "path reconstruction diverged");
            checked += 1;
        }
    }
    println!("\naccelerated_ceft: {checked} instances cross-validated OK");
}
