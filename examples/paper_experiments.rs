//! End-to-end validation driver: run the paper's whole evaluation pipeline
//! on a real (reduced-scale) workload and report the headline metrics next
//! to the paper's numbers. This is the run recorded in EXPERIMENTS.md.
//!
//! Pipeline exercised: graph generators → cost models → CEFT DP → CPOP/HEFT
//! baselines → CEFT-CPOP scheduler → metrics → aggregation, across all four
//! RGG workload families and the four real-world benchmarks, in parallel
//! via the coordinator.
//!
//! Run with: `cargo run --release --example paper_experiments [--scale paper-small]`

use ceft::coordinator::Coordinator;
use ceft::exp::cells::{realworld_grid, RealWorld, Scale, Workload};
use ceft::exp::figures::EQUAL_EPS;
use ceft::exp::run::run_realworld_sweep;
use ceft::metrics::{compare, WinTally};
use ceft::util::pool;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let scale = argv
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| argv.get(i + 1))
        .map(|s| Scale::parse(s).expect("bad scale"))
        .unwrap_or(Scale::PaperSmall);
    let threads = pool::default_threads();
    println!("paper_experiments: scale={scale:?} threads={threads}");

    let mut coord = Coordinator::new(threads, scale, "results".into(), true);

    // --- Table 3: the paper's headline -----------------------------------
    // paper: CEFT CPL shorter in 0 / 58.92 / 83.14 / 83.99 % and CEFT-CPOP
    // makespan shorter in 15.9 / 75.94 / 90.29 / 89.69 % of experiments
    // (RGG-classic / low / medium / high).
    println!("\n=== Table 3 (paper headline) ===");
    let paper_cpl_shorter = [0.0, 58.92, 83.14, 83.99];
    let paper_mk_shorter = [15.9, 75.94, 90.29, 89.69];
    for (i, wl) in Workload::ALL.into_iter().enumerate() {
        let rows = coord.rgg_rows(wl).to_vec();
        let mut cpl = WinTally::default();
        let mut mk = WinTally::default();
        for r in &rows {
            cpl.push(compare(r.cpl_ceft, r.cpl_cpop_realized, EQUAL_EPS));
            mk.push(compare(
                r.algo("CEFT-CPOP").makespan,
                r.algo("CPOP").makespan,
                EQUAL_EPS,
            ));
        }
        let (_, _, cpl_shorter) = cpl.percentages();
        let (_, _, mk_shorter) = mk.percentages();
        println!(
            "{:<12} CPL shorter: measured {:>6.2}% (paper {:>6.2}%)   makespan shorter: measured {:>6.2}% (paper {:>6.2}%)",
            wl.name(),
            cpl_shorter,
            paper_cpl_shorter[i],
            mk_shorter,
            paper_mk_shorter[i],
        );
    }

    // --- real-world benchmarks -------------------------------------------
    // paper §8.1: on medium variants CEFT paths shorter than CPOP's in
    // ~73.8% of cases, better makespans in ~77.77%.
    println!("\n=== Real-world benchmarks (medium variants) ===");
    let mut cpl = WinTally::default();
    let mut mk = WinTally::default();
    for fam in RealWorld::ALL {
        let cells = realworld_grid(fam, scale);
        let rows = run_realworld_sweep(&cells, threads, false);
        for r in rows.iter().filter(|r| r.workload.ends_with("medium")) {
            cpl.push(compare(r.cpl_ceft, r.cpl_cpop_realized, EQUAL_EPS));
            mk.push(compare(
                r.algo("CEFT-CPOP").makespan,
                r.algo("CPOP").makespan,
                EQUAL_EPS,
            ));
        }
    }
    let (_, _, cpl_s) = cpl.percentages();
    let (_, _, mk_s) = mk.percentages();
    println!(
        "CPL shorter: measured {cpl_s:.2}% (paper ~73.8%)   makespan shorter: measured {mk_s:.2}% (paper ~77.77%)"
    );

    // --- write every figure CSV -------------------------------------------
    println!("\n=== writing all figure CSVs to results/ ===");
    coord.produce_and_write("all").expect("write results");
    println!("done — see results/*.csv and EXPERIMENTS.md");
}
