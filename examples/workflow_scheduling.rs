//! Schedule the four real-world workflows of §7.2 (FFT, Gaussian
//! elimination, molecular dynamics, epigenomics) across CCR settings —
//! a compact version of the paper's Figures 15–18.
//!
//! Run with: `cargo run --release --example workflow_scheduling`

use ceft::graph::realworld::{
    epigenomics, fft, gaussian_elimination, molecular_dynamics, weighted_instance, Skeleton,
};
use ceft::metrics;
use ceft::platform::{CostModel, Platform};
use ceft::sched::{ceft_cpop::CeftCpop, cpop::Cpop, heft::Heft, Scheduler};
use ceft::util::csv::Table;

fn main() {
    let skeletons: Vec<Skeleton> = vec![
        fft(16),
        gaussian_elimination(12),
        molecular_dynamics(),
        epigenomics(12),
    ];
    let p = 8;
    let algos: [&dyn Scheduler; 3] = [&CeftCpop, &Cpop, &Heft];

    for skel in &skeletons {
        println!(
            "\n== {} ({} tasks, {} edges) ==",
            skel.name,
            skel.n,
            skel.edges.len()
        );
        let mut t = Table::new(vec![
            "ccr",
            "CEFT-CPOP slr",
            "CPOP slr",
            "HEFT slr",
            "CEFT-CPOP speedup",
            "CPOP speedup",
            "HEFT speedup",
        ]);
        for &ccr in &[0.1, 1.0, 10.0] {
            // average over a few seeds per CCR
            let mut slrs = [0.0f64; 3];
            let mut sps = [0.0f64; 3];
            let reps = 5;
            for seed in 0..reps {
                let platform = Platform::uniform(p, 1.0, 0.0);
                let inst = weighted_instance(
                    skel,
                    ccr,
                    50.0,
                    &CostModel::Classic { beta: 0.5 },
                    &platform,
                    seed,
                );
                let iref = inst.bind(&platform);
                for (i, a) in algos.iter().enumerate() {
                    let s = a.schedule(iref);
                    s.validate(iref).unwrap();
                    slrs[i] += metrics::slr(iref, s.makespan()) / reps as f64;
                    sps[i] += metrics::speedup(&inst.comp, s.makespan()) / reps as f64;
                }
            }
            t.push_row(vec![
                format!("{ccr}"),
                format!("{:.3}", slrs[0]),
                format!("{:.3}", slrs[1]),
                format!("{:.3}", slrs[2]),
                format!("{:.3}", sps[0]),
                format!("{:.3}", sps[1]),
                format!("{:.3}", sps[2]),
            ]);
        }
        print!("{}", t.to_ascii());
    }
    println!("\n(regenerate the full paper sweeps with `repro experiment fig15` … `fig18`)");
}
