//! Quickstart: build a small heterogeneous instance, find its CEFT critical
//! path, and schedule it with every algorithm.
//!
//! Run with: `cargo run --release --example quickstart`

use ceft::cp::ceft::find_critical_path;
use ceft::cp::ranks::cpop_critical_path;
use ceft::graph::TaskGraph;
use ceft::metrics;
use ceft::model::{CostMatrix, InstanceRef};
use ceft::platform::Platform;
use ceft::sched::{ceft_cpop::CeftCpop, cpop::Cpop, heft::Heft, Scheduler};

fn main() {
    // A small fork-join pipeline: preprocess -> {gpu-friendly kernel,
    // cpu-friendly kernel} -> reduce -> postprocess. Edge weights are data
    // volumes.
    let graph = TaskGraph::from_edges(
        5,
        &[
            (0, 1, 20.0),
            (0, 2, 20.0),
            (1, 3, 10.0),
            (2, 3, 10.0),
            (3, 4, 5.0),
        ],
    );

    // Two processor classes ("CPU", "GPU"), unit bandwidth, no startup cost.
    let platform = Platform::uniform(2, 1.0, 0.0);

    // Execution costs (v x P, task-major SoA): the array task is 10x faster
    // on the GPU class, the scalar task is hopeless there — the §1
    // motivating shape.
    #[rustfmt::skip]
    let comp = CostMatrix::new(2, vec![
        //  CPU    GPU
        5.0,   6.0,   // 0 preprocess
        80.0,  8.0,   // 1 array kernel: GPU 10x
        12.0,  90.0,  // 2 scalar kernel: CPU only
        6.0,   5.0,   // 3 reduce
        4.0,   4.0,   // 4 postprocess
    ]);
    let inst = InstanceRef::new(&graph, &platform, &comp);

    println!("== CEFT critical path (paper Algorithm 1) ==");
    let cp = find_critical_path(inst);
    println!("length = {:.2}", cp.length);
    for step in &cp.path {
        println!(
            "  task {} -> class {}  (exec {:.1})",
            step.task,
            step.class,
            comp.get(step.task, step.class)
        );
    }

    let (cpop_cp, cpop_len) = cpop_critical_path(inst);
    println!("\n== CPOP mean-value critical path ==");
    println!("tasks {:?}, estimated length {:.2}", cpop_cp, cpop_len);
    println!("(note how averaging distorts the path cost when tasks are specialised)");

    println!("\n== Schedules ==");
    let algos: [&dyn Scheduler; 3] = [&CeftCpop, &Cpop, &Heft];
    for a in algos {
        let s = a.schedule(inst);
        s.validate(inst).expect("valid schedule");
        println!(
            "{:<10} makespan {:>7.2}  speedup {:.3}  slr {:.3}",
            a.name(),
            s.makespan(),
            metrics::speedup(&comp, s.makespan()),
            metrics::slr(inst, s.makespan()),
        );
    }

    // Gantt view of the paper's scheduler
    let s = CeftCpop.schedule(inst);
    println!("\n== CEFT-CPOP Gantt (P0 = CPU class, P1 = GPU class) ==");
    print!("{}", ceft::sched::gantt::render(&s, 70));
}
