"""Pytest: the Pallas kernel vs the pure-jnp oracle — the core correctness
signal of the compile path, plus hypothesis sweeps over shapes/values."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import minplus, ref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(rng, b, p, data_scale=10.0, f_scale=100.0):
    f = rng.uniform(0.0, f_scale, size=(b, p)).astype(np.float32)
    data = rng.uniform(0.0, data_scale, size=(b,)).astype(np.float32)
    l = rng.uniform(0.0, 2.0, size=(p,)).astype(np.float32)
    invbw = rng.uniform(0.1, 2.0, size=(p, p)).astype(np.float32)
    np.fill_diagonal(invbw, 0.0)
    comp = rng.uniform(0.1, 50.0, size=(b, p)).astype(np.float32)
    return f, data, l, invbw, comp


@pytest.mark.parametrize("p", [2, 4, 8, 16, 32, 64])
def test_kernel_matches_reference_all_class_sizes(p):
    rng = np.random.default_rng(p)
    args = make_inputs(rng, minplus.TILE_B, p)
    out = minplus.relax(*map(jnp.asarray, args))
    expect = ref.relax_reference(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("blocks", [1, 2, 4])
def test_kernel_multi_block_grid(blocks):
    rng = np.random.default_rng(blocks)
    args = make_inputs(rng, minplus.TILE_B * blocks, 8)
    out = minplus.relax(*map(jnp.asarray, args))
    expect = ref.relax_reference(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6, atol=1e-5)


def test_same_class_comm_is_free():
    # one parent at 10.0 on class 0; child on class 0 must not pay comm
    p = 4
    b = minplus.TILE_B
    f = np.full((b, p), 1e6, np.float32)
    f[:, 0] = 10.0
    data = np.full((b,), 1e5, np.float32)  # enormous payload
    l = np.ones((p,), np.float32)
    invbw = np.ones((p, p), np.float32)
    np.fill_diagonal(invbw, 0.0)
    comp = np.ones((b, p), np.float32)
    out = np.asarray(minplus.relax(*map(jnp.asarray, (f, data, l, invbw, comp))))
    # class 0: arrival = 10 (no comm), +1 comp
    np.testing.assert_allclose(out[:, 0], 11.0)
    # class 1: best is still from class 0 but pays 1 + 1e5
    np.testing.assert_allclose(out[:, 1], 10.0 + 1.0 + 1e5 + 1.0)


def test_zero_data_still_pays_startup():
    p = 2
    b = minplus.TILE_B
    f = np.zeros((b, p), np.float32)
    f[:, 1] = 1e6
    data = np.zeros((b,), np.float32)
    l = np.array([3.0, 5.0], np.float32)
    invbw = np.ones((p, p), np.float32)
    np.fill_diagonal(invbw, 0.0)
    comp = np.zeros((b, p), np.float32)
    out = np.asarray(minplus.relax(*map(jnp.asarray, (f, data, l, invbw, comp))))
    # dest class 1: from class 0 pays L[0]=3 even with zero payload
    np.testing.assert_allclose(out[:, 1], 3.0)
    np.testing.assert_allclose(out[:, 0], 0.0)


@settings(max_examples=30, deadline=None)
@given(
    p=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    data_scale=st.sampled_from([0.0, 0.1, 10.0, 1e4]),
)
def test_kernel_matches_reference_hypothesis(p, seed, data_scale):
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, minplus.TILE_B, p, data_scale=data_scale)
    out = minplus.relax(*map(jnp.asarray, args))
    expect = ref.relax_reference(*map(jnp.asarray, args))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_relaxation_monotone_in_parent_values(seed):
    # CEFT monotonicity: raising any parent value cannot lower any output
    rng = np.random.default_rng(seed)
    f, data, l, invbw, comp = make_inputs(rng, minplus.TILE_B, 4)
    out1 = np.asarray(minplus.relax(*map(jnp.asarray, (f, data, l, invbw, comp))))
    bump = f + rng.uniform(0.0, 5.0, size=f.shape).astype(np.float32)
    out2 = np.asarray(minplus.relax(*map(jnp.asarray, (bump, data, l, invbw, comp))))
    assert (out2 >= out1 - 1e-4).all()


def test_output_lower_bound_is_colocated_path():
    # out[b, j] >= F[b, j] + comp[b, j] can fail (another class may be
    # cheaper), but out[b, j] <= F[b, j] + comp[b, j] always holds: the
    # co-located candidate is in the min.
    rng = np.random.default_rng(99)
    f, data, l, invbw, comp = make_inputs(rng, minplus.TILE_B, 8)
    out = np.asarray(minplus.relax(*map(jnp.asarray, (f, data, l, invbw, comp))))
    assert (out <= f + comp + 1e-4).all()


def test_vmem_estimate_within_tpu_budget():
    # structural perf check (DESIGN.md §Perf): worst-case block fits VMEM
    assert minplus.vmem_bytes(minplus.TILE_B, 64) < 16 * 2**20
