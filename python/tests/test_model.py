"""Pytest: Layer-2 model functions and the AOT export path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import minplus, ref

jax.config.update("jax_platform_name", "cpu")


def inputs(b, p, seed=0):
    rng = np.random.default_rng(seed)
    f = rng.uniform(0, 100, (b, p)).astype(np.float32)
    data = rng.uniform(0, 10, (b,)).astype(np.float32)
    l = rng.uniform(0, 1, (p,)).astype(np.float32)
    invbw = rng.uniform(0.5, 1.5, (p, p)).astype(np.float32)
    np.fill_diagonal(invbw, 0.0)
    comp = rng.uniform(1, 20, (b, p)).astype(np.float32)
    return tuple(map(jnp.asarray, (f, data, l, invbw, comp)))


def test_relax_batch_equals_kernel():
    args = inputs(minplus.TILE_B, 8)
    out = model.ceft_relax_batch(*args)
    expect = ref.relax_reference(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_relax_multi_equals_repeated_single():
    args = inputs(minplus.TILE_B, 4, seed=3)
    f = args[0]
    out_multi = model.ceft_relax_multi(f, *args[1:], steps=3)
    cur = f
    for _ in range(3):
        cur = model.ceft_relax_batch(cur, *args[1:])
    np.testing.assert_allclose(np.asarray(out_multi), np.asarray(cur), rtol=1e-6)


def test_ceft_table_reference_chain():
    # 3-task chain, hand-checkable (mirrors the rust unit test)
    comp = jnp.array([[1.0, 10.0], [10.0, 2.0], [3.0, 10.0]], jnp.float32)
    l = jnp.zeros((2,), jnp.float32)
    invbw = jnp.array([[0.0, 1e-9], [1e-9, 0.0]], jnp.float32)  # ~free comm
    preds = [[], [(0, 100.0)], [(1, 100.0)]]
    table = ref.ceft_table_reference(3, preds, comp, l, invbw)
    # task 2 class 0: 1 + 2 + 3 = 6 (within float noise of free comm)
    assert abs(float(table[2, 0]) - 6.0) < 1e-3


def test_hlo_export_produces_parseable_text():
    text = aot.export_relax(p=2, batch=minplus.TILE_B)
    assert "HloModule" in text
    assert "ENTRY" in text
    # all five parameters present
    for i in range(5):
        assert f"parameter({i})" in text, f"missing parameter {i}"


def test_hlo_export_is_deterministic():
    a = aot.export_relax(p=4)
    b = aot.export_relax(p=4)
    assert a == b


@pytest.mark.parametrize("p", [2, 8])
def test_exported_computation_runs_via_jax_and_matches(p):
    # execute the lowered computation through jax itself (CPU) and compare
    # against the oracle — validates the exact artifact the rust side loads
    args = inputs(minplus.TILE_B, p, seed=7)
    lowered = jax.jit(model.ceft_relax_batch).lower(
        *model.example_args(minplus.TILE_B, p)
    )
    compiled = lowered.compile()
    out = compiled(*args)
    expect = ref.relax_reference(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)
