"""Layer 1 — the CEFT edge-relaxation Pallas kernel.

The numeric hot spot of the CEFT dynamic program (Algorithm 1 of the paper)
is the per-edge relaxation

    out[b, j] = min_l ( F[b, l] + comm(l, j, data[b]) ) + comp[b, j]
    comm(l, j, d) = 0                       if l == j
                  = L[l] + d * invbw[l, j]  otherwise

i.e. a batched *tropical (min-plus) matrix product* between the parent CEFT
rows F (B x P) and the communication-cost matrix (P x P, data-dependent per
edge), followed by the elementwise add of the child execution costs.

TPU mapping (DESIGN.md §Hardware-Adaptation): tropical algebra cannot use
the MXU (a bf16 ring-matmul systolic array), so the kernel targets the VPU
with the P_l reduction materialised as a (B, P_l, P_j) broadcast inside a
VMEM tile and min-reduced over axis 1. BlockSpec tiles the batch dimension
so HBM->VMEM traffic is one F/comp tile per block; L/invbw are tiny and
replicated into every block. VMEM per block = TILE_B*(2P + P) + P^2 + P
floats — ~144 KiB at TILE_B=256, P=64 — far under a TPU core's ~16 MiB.

interpret=True everywhere on CPU: real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile along the edge-batch dimension. 256 edges per block keeps the
# broadcast tensor (TILE_B x P x P) under 4 MiB for P = 64 in f32.
TILE_B = 256


def _relax_kernel(f_ref, data_ref, l_ref, invbw_ref, comp_ref, out_ref):
    """Pallas kernel body: one (TILE_B, P) block of the relaxation.

    f_ref:     (TILE_B, P)  parent CEFT values for each edge in the block
    data_ref:  (TILE_B, 1)  payload of each edge
    l_ref:     (1, P)       per-class communication startup latency
    invbw_ref: (P, P)       reciprocal bandwidth (diagonal ignored)
    comp_ref:  (TILE_B, P)  child execution cost on each class
    out_ref:   (TILE_B, P)  relaxed CEFT candidates
    """
    f = f_ref[...]  # (B, P)
    data = data_ref[...]  # (B, 1)
    lat = l_ref[...]  # (1, P)
    invbw = invbw_ref[...]  # (P, P)
    comp = comp_ref[...]  # (B, P)

    p = f.shape[1]
    # comm[b, l, j] = L[l] + data[b] * invbw[l, j], zeroed on the diagonal.
    # Build the (B, P_l, P_j) tensor in VMEM; the l-axis is the reduction.
    comm = lat.reshape(1, p, 1) + data[:, :, None] * invbw[None, :, :]
    eye = jnp.eye(p, dtype=f.dtype)
    comm = jnp.where(eye[None, :, :] > 0, jnp.zeros_like(comm), comm)
    # tropical contraction: min over l of F[b, l] + comm[b, l, j]
    arrival = jnp.min(f[:, :, None] + comm, axis=1)  # (B, P_j)
    out_ref[...] = arrival + comp


@functools.partial(jax.jit, static_argnames=("interpret",))
def relax(f, data, l, invbw, comp, *, interpret=True):
    """Batched CEFT edge relaxation via the Pallas kernel.

    Args:
      f:      (B, P) float32 — parent CEFT rows.
      data:   (B,)   float32 — edge payloads.
      l:      (P,)   float32 — per-class startup latency.
      invbw:  (P, P) float32 — reciprocal bandwidths (diagonal ignored).
      comp:   (B, P) float32 — child execution costs.
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      (B, P) float32 — min-plus relaxed CEFT candidates.
    """
    b, p = f.shape
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    grid = (b // TILE_B,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, p), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((TILE_B, p), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p), f.dtype),
        interpret=interpret,
    )(f, data.reshape(b, 1), l.reshape(1, p), invbw, comp)


def vmem_bytes(tile_b: int, p: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one kernel block (see module docstring).

    Counts the resident operands, the output tile, and the dominant
    intermediate (the (tile_b, p, p) comm/broadcast tensor).
    """
    operands = tile_b * p * 2 + tile_b + p + p * p + tile_b * p
    intermediate = tile_b * p * p
    return (operands + intermediate) * dtype_bytes
