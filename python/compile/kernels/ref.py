"""Pure-jnp oracle for the Layer-1 relaxation kernel.

This is the correctness contract: `minplus.relax` must agree with
`ref.relax_reference` to float32 tolerance for every shape and input
distribution (pytest + hypothesis sweep in python/tests/test_kernel.py),
and the rust `runtime::relax_batch_reference` mirrors the same semantics
on the other side of the AOT boundary.
"""

from __future__ import annotations

import jax.numpy as jnp


def comm_matrix(data, l, invbw):
    """(B, P, P) communication costs: comm[b, l, j] per Definition 3.

    Zero on the diagonal (co-located tasks), else L[l] + data[b]*invbw[l,j].
    """
    b = data.shape[0]
    p = l.shape[0]
    comm = l.reshape(1, p, 1) + data.reshape(b, 1, 1) * invbw.reshape(1, p, p)
    eye = jnp.eye(p, dtype=comm.dtype).reshape(1, p, p)
    return jnp.where(eye > 0, jnp.zeros_like(comm), comm)


def relax_reference(f, data, l, invbw, comp):
    """out[b, j] = min_l (F[b, l] + comm[b, l, j]) + comp[b, j]."""
    comm = comm_matrix(data, l, invbw)
    arrival = jnp.min(f[:, :, None] + comm, axis=1)
    return arrival + comp


def ceft_table_reference(n, preds, comp, l, invbw):
    """Whole-graph CEFT table in pure numpy-ish jnp, for model-level tests.

    Args:
      n: number of tasks.
      preds: list over tasks of lists of (parent, data) pairs; tasks must be
        topologically ordered (parent < child).
      comp: (n, P) execution costs.
      l, invbw: platform comm parameters.

    Returns:
      (n, P) CEFT values.
    """
    p = comp.shape[1]
    table = [None] * n
    for t in range(n):
        if not preds[t]:
            table[t] = comp[t]
            continue
        best = None
        for (k, data) in preds[t]:
            comm = comm_matrix(jnp.array([data], comp.dtype), l, invbw)[0]
            arrival = jnp.min(table[k][:, None] + comm, axis=0)
            best = arrival if best is None else jnp.maximum(best, arrival)
        table[t] = best + comp[t]
    return jnp.stack(table)
