"""AOT export: lower the Layer-2 function to HLO text artifacts.

HLO *text* (not `.serialize()`d protos) is the interchange format: jax >=
0.5 emits HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per processor-class count:
    ceft_relax_b256_p{2,4,8,16,32,64}.hlo.txt
plus a manifest.json describing shapes, and is a no-op when artifacts are
newer than the python sources (the Makefile also guards this).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

BATCH = 256
CLASS_SIZES = [2, 4, 8, 16, 32, 64]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_relax(p: int, batch: int = BATCH) -> str:
    """Lower ceft_relax_batch for (batch, p) and return HLO text."""
    args = model.example_args(batch, p)
    lowered = jax.jit(model.ceft_relax_batch).lower(*args)
    return to_hlo_text(lowered)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--class-sizes",
        default=",".join(str(p) for p in CLASS_SIZES),
        help="comma-separated processor-class counts",
    )
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--force", action="store_true", help="re-export even if fresh")
    ns = ap.parse_args(argv)

    os.makedirs(ns.out_dir, exist_ok=True)
    sizes = [int(s) for s in ns.class_sizes.split(",") if s]
    manifest = {"batch": ns.batch, "class_sizes": sizes, "artifacts": {}}
    src_mtime = max(
        os.path.getmtime(f)
        for f in [
            __file__,
            os.path.join(os.path.dirname(__file__), "model.py"),
            os.path.join(os.path.dirname(__file__), "kernels", "minplus.py"),
        ]
    )
    for p in sizes:
        name = f"ceft_relax_b{ns.batch}_p{p}.hlo.txt"
        path = os.path.join(ns.out_dir, name)
        fresh = (
            not ns.force
            and os.path.exists(path)
            and os.path.getmtime(path) >= src_mtime
        )
        if fresh:
            print(f"fresh: {name}")
        else:
            text = export_relax(p, ns.batch)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {name} ({len(text)} chars)")
        manifest["artifacts"][str(p)] = name
    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
