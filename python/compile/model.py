"""Layer 2 — the JAX compute graph around the Layer-1 kernel.

The rust coordinator drives the CEFT dynamic program level by level; the
exported computation is the *edge-relaxation batch*: the per-level inner
loop of Algorithm 1 over a fixed-size batch of edges. `ceft_relax_batch`
wraps the Pallas kernel so it lowers into the exported HLO; `aot.py`
exports one artifact per processor-class count.

A fused multi-step variant (`ceft_relax_multi`) runs K relaxation rounds in
one call via `lax.scan` — used to amortise PJRT call overhead for deep
chain-like graphs, and to exercise scan-lowering through the AOT path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import minplus


def ceft_relax_batch(f, data, l, invbw, comp):
    """One batched CEFT edge relaxation (the Algorithm-1 inner loop).

    Shapes: f (B, P), data (B,), l (P,), invbw (P, P), comp (B, P).
    Returns (B, P). B must be a multiple of minplus.TILE_B.
    """
    return minplus.relax(f, data, l, invbw, comp, interpret=True)


def ceft_relax_multi(f, data, l, invbw, comp, steps: int):
    """`steps` chained relaxations of the same edge batch.

    Feeds each round's output back as the next round's parent rows —
    the fixed-point iteration view of the DP on a chain. Lowered with
    `lax.scan` so the exported HLO contains a single rolled loop instead of
    `steps` unrolled kernel bodies (smaller artifact, same numerics).
    """

    def step(carry, _):
        out = ceft_relax_batch(carry, data, l, invbw, comp)
        return out, ()

    out, _ = jax.lax.scan(step, f, None, length=steps)
    return out


def example_args(b: int, p: int, dtype=jnp.float32):
    """ShapeDtypeStructs matching one artifact signature."""
    return (
        jax.ShapeDtypeStruct((b, p), dtype),  # f
        jax.ShapeDtypeStruct((b,), dtype),  # data
        jax.ShapeDtypeStruct((p,), dtype),  # l
        jax.ShapeDtypeStruct((p, p), dtype),  # invbw
        jax.ShapeDtypeStruct((b, p), dtype),  # comp
    )
