#!/usr/bin/env bash
# CI entry point: fmt + clippy gates, build, test, run the quickstart +
# online-service examples, round-trip the serve/request protocol over TCP
# (including a fault-injected chaos pass), record loadgen perf — with the
# overload/fault gates — to BENCH_service.json, and smoke the throughput
# bench.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
  # Some build containers carry no rust toolchain; the driver runs the
  # tier-1 gate (cargo build + cargo test) in an environment that does.
  echo "ci.sh: cargo not found — skipping (tier-1 runs in the driver)"
  exit 0
fi

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --check
else
  echo "skipped: rustfmt component not installed"
fi

echo "== build =="
cargo build --release

echo "== clippy (incl. deprecated-shim gate) =="
if cargo clippy --version >/dev/null 2>&1; then
  # -D warnings gates correctness lints; the -A list covers style idioms
  # this codebase uses deliberately (documented many-arg experiment rows,
  # index-and-position loops in the DP kernels, the inherent Json
  # serialiser named to_string). The explicit -D deprecated keeps new code
  # from routing through the #[deprecated] raw-triple shims
  # (cost_matrix_from_raw, find_critical_path_raw, schedule_raw) even if
  # the -A list ever grows a blanket allow; the shims' own tests opt back
  # in with #[allow(deprecated)].
  cargo clippy --all-targets -- -D warnings -D deprecated \
    -A clippy::too_many_arguments \
    -A clippy::type_complexity \
    -A clippy::needless_range_loop \
    -A clippy::inherent_to_string
else
  echo "skipped: clippy component not installed"
fi

echo "== tests =="
cargo test -q

echo "== quickstart example =="
cargo run --release --example quickstart

echo "== online service example (in-process engine) =="
cargo run --release --example online_service

echo "== serve/request round trip (TCP) =="
ADDR="127.0.0.1:17077"
./target/release/repro serve --addr "$ADDR" &
SERVER_PID=$!
trap 'kill $SERVER_PID 2>/dev/null || true' EXIT

# wait for the listener to come up
for i in $(seq 1 50); do
  if ./target/release/repro request --addr "$ADDR" --op ping >/dev/null 2>&1; then
    break
  fi
  sleep 0.1
done

./target/release/repro request --addr "$ADDR" --op ping
SUBMIT_RESP=$(./target/release/repro request --addr "$ADDR" --op submit --n 64 --p 4)
echo "$SUBMIT_RESP"
HANDLE=$(echo "$SUBMIT_RESP" | sed -n 's/.*"id":"\([0-9a-f]*\)".*/\1/p')
./target/release/repro request --addr "$ADDR" --op cp --n 64 --p 4
./target/release/repro request --addr "$ADDR" --op schedule --algorithm CEFT-CPOP --n 64 --p 4
# the identical request again must be a cache hit
./target/release/repro request --addr "$ADDR" --op schedule --algorithm CEFT-CPOP --n 64 --p 4 \
  | grep -q '"cached":true'
# incremental update round trip: cp with slack exposes the per-task array,
# an in-place edit bumps the generation and reports its delta economy, and
# the follow-up cp serves the edited generation (still with slack)
./target/release/repro request --addr "$ADDR" --op cp --id "$HANDLE" --slack true \
  | grep -q '"slack":\['
UPDATE_RESP=$(./target/release/repro request --addr "$ADDR" --op update --id "$HANDLE" \
  --edits '[{"edit":"task_cost","task":1,"costs":[2.5,2.5,2.5,2.5]},{"edit":"add_edge","src":0,"dst":63,"data":1.0}]')
echo "$UPDATE_RESP"
echo "$UPDATE_RESP" | grep -q '"generation":1'
echo "$UPDATE_RESP" | grep -q '"slack":\['
echo "$UPDATE_RESP" | grep -q '"delta_rows_recomputed"'
echo "$UPDATE_RESP" | grep -q '"skipped":'
./target/release/repro request --addr "$ADDR" --op cp --id "$HANDLE" --slack true \
  | grep -q '"slack":\['
./target/release/repro request --addr "$ADDR" --op stats
# telemetry surfacing: the trace op must render the full 8-stage table,
# and the metrics op the Prometheus-style exposition
./target/release/repro request --addr "$ADDR" --op trace --limit 4 | grep -q 'queue_wait'
./target/release/repro request --addr "$ADDR" --op metrics | grep -q 'ceft_stage_latency_seconds'
./target/release/repro request --addr "$ADDR" --op shutdown
wait "$SERVER_PID"
trap - EXIT

echo "== chaos serve/request round trip (fault injection over TCP) =="
# A server armed with one injected kernel panic: the first uncached cp dies
# mid-gather, the client's --retries turns the structured internal_panic
# into a served answer, and the resilience counters record the whole story.
CADDR="127.0.0.1:17078"
./target/release/repro serve --addr "$CADDR" --fault-plan "seed=0,kernel_panic=1x1" &
CHAOS_PID=$!
trap 'kill $CHAOS_PID 2>/dev/null || true' EXIT
for i in $(seq 1 50); do
  if ./target/release/repro request --addr "$CADDR" --op ping >/dev/null 2>&1; then
    break
  fi
  sleep 0.1
done
# the injected panic is retried into a real answer (exit 0 == served)
./target/release/repro request --addr "$CADDR" --op cp --n 64 --p 4 --retries 3
# an expired budget on an uncached instance is a structured refusal with a
# backoff hint — and the connection/server survive it
DRESP=$(./target/release/repro request --addr "$CADDR" --op cp --n 96 --p 4 --deadline-ms 0 || true)
echo "$DRESP" | grep -q '"error":"deadline_exceeded"'
echo "$DRESP" | grep -q '"retry_after_ms"'
# the panic was caught exactly once and surfaced in stats + metrics
STATS_RESP=$(./target/release/repro request --addr "$CADDR" --op stats)
echo "$STATS_RESP" | grep -q '"resilience"'
echo "$STATS_RESP" | grep -q '"panics_caught":1'
echo "$STATS_RESP" | grep -q '"deadline_expired":1'
./target/release/repro request --addr "$CADDR" --op metrics \
  | grep -q 'ceft_resilience_panics_caught_total'
# graceful drain: the faulted server still shuts down cleanly
./target/release/repro request --addr "$CADDR" --op shutdown
wait "$CHAOS_PID"
trap - EXIT

echo "== loadgen smoke (writes BENCH_service.json) =="
# --platform-mix 3 exercises the per-platform panel cache: loadgen itself
# fails unless panel_ctx_misses == 3 (panels built once per platform).
./target/release/repro loadgen --n 64 --p 4 --count 8 --platform-mix 3 --rate 200 --duration 1
grep -q '"achieved_rps"' BENCH_service.json
# The committed schema placeholder has requests == 0; a regenerated report
# must never look like that, or the perf trajectory tracks a non-run.
if grep -q '"requests":0[,}]' BENCH_service.json; then
  echo "BENCH_service.json still reports requests == 0 — loadgen produced no measurement"
  exit 1
fi
# The report must carry the panel-cache section, or the panel-residency
# regression the counters exist to catch would go unmeasured.
if ! grep -q '"panel_ctx_hits"' BENCH_service.json; then
  echo "BENCH_service.json lacks the panel-cache counters (panel_ctx_hits/panel_ctx_misses)"
  exit 1
fi
# ... and the cross-request batching section: the platform-mix run replays
# a cp-share (default 0.25), so the engine's batched_requests/batch_width
# counters are live and batch-efficiency must be recorded.
if ! grep -q '"batch_efficiency"' BENCH_service.json; then
  echo "BENCH_service.json lacks the batch-efficiency field (cross-request batching unmeasured)"
  exit 1
fi
# Telemetry fields: the regenerated report must carry the per-stage
# percentiles (loadgen itself already fails if any always-on stage
# recorded no samples) and the telemetry on/off A/B overhead number.
if ! grep -q '"stages"' BENCH_service.json; then
  echo "BENCH_service.json lacks the per-stage latency section"
  exit 1
fi
if ! grep -q '"telemetry_overhead_pct"' BENCH_service.json; then
  echo "BENCH_service.json lacks the telemetry A/B overhead field"
  exit 1
fi
if ! grep -q '"p99_us"' BENCH_service.json; then
  echo "BENCH_service.json stage histograms carry no percentile fields"
  exit 1
fi

echo "== loadgen smoke, structured fork-join workload (SP fast path) =="
# A pure fork-join workload must route every full-table computation through
# the series-parallel tree-DP kernel: loadgen itself exits nonzero if
# shape_fast_path_hits stays zero, and the report (kept out of
# BENCH_service.json — the tracked record is the sweep below) must carry
# the shape counters and per-shape latency rows.
./target/release/repro loadgen --n 64 --p 4 --count 8 --shape fork-join \
  --rate 200 --duration 1 --json-out BENCH_shape_smoke.json
grep -q '"shape":"fork-join"' BENCH_shape_smoke.json
grep -q '"shape_fast_path_hits"' BENCH_shape_smoke.json
grep -q '"per_shape_p99_us"' BENCH_shape_smoke.json
rm -f BENCH_shape_smoke.json

echo "== loadgen smoke with telemetry disabled =="
# CEFT_TELEMETRY=off must leave every hook a no-op end to end: the replay
# still succeeds, and the report (kept out of BENCH_service.json — this is
# a functional check, not the tracked measurement) says telemetry off.
CEFT_TELEMETRY=off ./target/release/repro loadgen --n 64 --p 4 --count 8 \
  --rate 200 --duration 1 --json-out BENCH_telemetry_off.json
grep -q '"telemetry":"off"' BENCH_telemetry_off.json
rm -f BENCH_telemetry_off.json

echo "== loadgen cp-share sweep (schedule batching, writes BENCH_service.json) =="
# Sweep the cp/schedule mix from schedule-only (0.0) to cp-only (1.0).
# --threads 2 --clients 8 oversubscribes the workers so concurrent misses
# pile past the saturation gate; 48 distinct instances give every point a
# real miss storm. loadgen itself exits nonzero if a schedule-heavy point
# gathers zero requests or the 0.0-endpoint batch efficiency falls below
# half the cp-only baseline; the greps pin the report schema the gates
# read. --edit-share 0.25 adds in-place update traffic to every point:
# loadgen exits nonzero unless updates are delta-served and every
# delta-served update stays within the tail-decile row bound. This sweep
# is the tracked BENCH_service.json record. --chaos appends the
# overload/fault pass: loadgen exits nonzero unless availability stays
# >= 99%, every surviving (and post-fault recomputed) answer is
# bit-identical to a fault-free baseline, injected panics were caught and
# retried, and the served p99 holds against the unshedded run.
./target/release/repro loadgen --n 128 --p 8 --count 48 --rate 2000 --duration 1 \
  --threads 2 --clients 8 --batch-window 8 --cp-share 0.0,0.25,0.5,1.0 \
  --edit-share 0.25 --chaos
grep -q '"sweep":"cp_share"' BENCH_service.json
# every point must carry the table-cache counters: the memoized CEFT-table
# layer is what both cp and schedule traffic now batch through
if ! grep -q '"table_cache_hits"' BENCH_service.json; then
  echo "BENCH_service.json lacks the table_cache counters (table memo unmeasured)"
  exit 1
fi
if ! grep -q '"cp_schedule_shares"' BENCH_service.json; then
  echo "BENCH_service.json lacks the cp_schedule_shares counter (cross-workload reuse unmeasured)"
  exit 1
fi
# the schedule-only endpoint must hold the batch-efficiency floor vs the
# cp-only baseline — false here means schedule traffic fell off the
# gathered sweeps
if ! grep -q '"sweep_batch_floor_ok":true' BENCH_service.json; then
  echo "BENCH_service.json reports sweep_batch_floor_ok != true — schedule batching regressed"
  exit 1
fi
# the incremental-recompute economy must be recorded: rows recomputed vs a
# from-scratch sweep and their ratio (see EXPERIMENTS.md §Incremental
# re-scheduling)
if ! grep -q '"delta_speedup"' BENCH_service.json; then
  echo "BENCH_service.json lacks the delta_speedup field (incremental recompute unmeasured)"
  exit 1
fi
if ! grep -q '"delta_rows_recomputed"' BENCH_service.json; then
  echo "BENCH_service.json lacks the delta_rows_recomputed counter"
  exit 1
fi
# every point must carry the shape-routing counters: the interning-time
# recognizer and the SP fast path are live on every workload, so the
# hits/fallbacks split (and per-shape p99) belongs in the tracked record
if ! grep -q '"shape_fast_path_hits"' BENCH_service.json; then
  echo "BENCH_service.json lacks the shape_fast_path_hits counter (SP routing unmeasured)"
  exit 1
fi
if ! grep -q '"per_shape_p99_us"' BENCH_service.json; then
  echo "BENCH_service.json lacks the per_shape_p99_us rows"
  exit 1
fi
# The overload/fault record: every entry carries the resilience counters,
# and the chaos pass must have passed its own gates with both bit-identity
# checks green — a faulted past that leaves numeric residue is the exact
# regression this section exists to catch.
for field in '"availability_pct"' '"shed_requests"' '"deadline_expired"' '"panics_caught"'; do
  if ! grep -q "$field" BENCH_service.json; then
    echo "BENCH_service.json lacks the resilience field $field"
    exit 1
  fi
done
if ! grep -q '"chaos"' BENCH_service.json; then
  echo "BENCH_service.json lacks the chaos section (overload/fault pass unrecorded)"
  exit 1
fi
grep -q '"chaos_bit_identical":true' BENCH_service.json
grep -q '"post_fault_bit_identical":true' BENCH_service.json
grep -q '"gates_passed":true' BENCH_service.json

echo "== service throughput bench (smoke) =="
CEFT_BENCH_FAST=1 cargo bench --bench service_throughput

echo "== ceft kernel bench (smoke, both dispatch paths) =="
# forced-scalar first, default (SIMD) second: both env dispatch paths get
# exercised end to end, and the BENCH_kernel.json left behind records the
# default-dispatch run
CEFT_FORCE_SCALAR=1 CEFT_BENCH_FAST=1 cargo bench --bench ceft_kernel
CEFT_BENCH_FAST=1 cargo bench --bench ceft_kernel
# the kernel perf record seeds the throughput trajectory — gate on it
# existing and carrying real per-case rows
if [ ! -s BENCH_kernel.json ]; then
  echo "BENCH_kernel.json missing or empty — kernel bench produced no record"
  exit 1
fi
if ! grep -q '"cells_per_s"' BENCH_kernel.json; then
  echo "BENCH_kernel.json lacks the per-case cells_per_s rows"
  exit 1
fi
if grep -q '"n":0' BENCH_kernel.json; then
  echo "BENCH_kernel.json still carries the schema placeholder — bench produced no measurement"
  exit 1
fi
# the telemetry on/off kernel rows must be present: the per-dispatch
# KernelTimer cost is tracked alongside the throughput trajectory
if ! grep -q '"telemetry"' BENCH_kernel.json; then
  echo "BENCH_kernel.json lacks the telemetry on/off A/B section"
  exit 1
fi
# ... and the gathered-tables row: the multi-instance table sweep is the
# engine's batch-drain shape, so its cells/s sits in the tracked record
if ! grep -q '"gathered_tables"' BENCH_kernel.json; then
  echo "BENCH_kernel.json lacks the gathered_tables throughput row"
  exit 1
fi
# ... and the delta_suffix rows: the dirty-suffix incremental kernel's
# throughput at 10/50/90% suffix shares is part of the tracked record
if ! grep -q '"delta_suffix_10pct"' BENCH_kernel.json; then
  echo "BENCH_kernel.json lacks the delta_suffix throughput rows"
  exit 1
fi
# ... and the sp_tree rows: the series-parallel tree-DP kernel's cells/s
# over recognizer-decomposed fork-join and pipeline instances is part of
# the tracked record (EXPERIMENTS.md §Structured-graph fast paths)
if ! grep -q '"sp_tree_fork_join"' BENCH_kernel.json; then
  echo "BENCH_kernel.json lacks the sp_tree_fork_join throughput row"
  exit 1
fi
if ! grep -q '"sp_tree_pipeline"' BENCH_kernel.json; then
  echo "BENCH_kernel.json lacks the sp_tree_pipeline throughput row"
  exit 1
fi

echo "== doc gate (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "ci.sh: all green"
